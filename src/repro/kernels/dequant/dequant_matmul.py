"""Fused dequantize-matmul Pallas TPU kernel.

The serving hot spot of WaterSIC-quantized models: weights live in HBM as
int8 ZSIC codes Z (out, in) plus a fused per-column scale s = α⊙γ (the 16/n
overhead of Alg. 3) and per-row scale t (the 16/a overhead).  The effective
weight is  Ŵ[o, i] = t[o]·Z[o, i]·s[i]  and the layer computes

    out[b, o] = Σ_i x[b, i] · Ŵ[o, i]
              = t[o] · Σ_i (x[b, i]·s[i]) · Z[o, i]

Fusing the dequantization into the matmul means the bf16 weight matrix never
round-trips through HBM — at decode batch sizes the matmul is weight-bytes
bound, so int8 codes cut the dominant roofline term ~2× vs bf16, and the
nibble-packed int4 variant (``dequant_matmul_packed_pallas``) cuts it 4×:
the kernel streams uint8 planar-packed codes from HBM and unpacks them
in-VMEM (shift/mask/sign-extend on the VPU) right before the MXU dot, so
HBM only ever sees half a byte per weight (DESIGN.md §8).  The column
scaling is applied to the *activation tile* (n ops per tile instead of
a·n), the row scaling to the accumulator.

Grid: (M/bm, N/bn, K/bk), K innermost (sequential) with an f32 VMEM
accumulator; MXU dims (bm, bn, bk) are multiples of 128 by construction in
ops.py.  The packed kernel contracts over *byte* blocks (bkh = bk/2): the
planar layout (byte j = col j | col j+K/2 << 4, core/packing) lets it dot
the low-nibble plane against the first half of the activation columns and
the high-nibble plane against the second half — two contiguous MXU dots,
no lane interleave.  Out-of-range escapes are applied OUTSIDE the kernel
as a sparse COO correction (ops._apply_escapes), keeping the hot loop
branch-free.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["dequant_matmul_pallas", "dequant_matmul_packed_pallas"]


def _kernel(x_ref, z_ref, s_ref, t_ref, o_ref, acc_ref, *, n_k: int):
    """One (bm, bn) output tile; accumulate over the K grid dimension.

    x_ref: (bm, bk) activations        s_ref: (1, bk) column scales (α⊙γ)
    z_ref: (bn, bk) int8 codes         t_ref: (1, bn) row scales
    o_ref: (bm, bn) output             acc_ref: (bm, bn) f32 VMEM scratch
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xs = x_ref[...].astype(jnp.float32) * s_ref[...].astype(jnp.float32)
    z = z_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        xs, z, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _store():
        o_ref[...] = (acc_ref[...] * t_ref[...].astype(jnp.float32)
                      ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "interpret",
                     "out_dtype"))
def dequant_matmul_pallas(x, z, col_scale, row_scale, *,
                          block_m: int = 128, block_n: int = 128,
                          block_k: int = 512, interpret: bool = False,
                          out_dtype=jnp.float32):
    """x (m, k) · dequant(z (n, k), s (k,), t (n,))ᵀ → (m, n).

    All dims must be multiples of the block sizes (ops.py pads).
    """
    m, k = x.shape
    n, k2 = z.shape
    assert k == k2, (x.shape, z.shape)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        (m, n, k), (block_m, block_n, block_k))
    n_k = k // block_k
    grid = (m // block_m, n // block_n, n_k)
    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_n, block_k), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((1, block_k), lambda i, j, kk: (0, kk)),
            pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, z, col_scale.reshape(1, k), row_scale.reshape(1, n))


def _sign_extend_nibble(v):
    """uint8 nibble (0..15, already widened to int32) → int4 value in f32."""
    return jnp.where(v > 7, v - 16, v).astype(jnp.float32)


def _packed_kernel(xlo_ref, xhi_ref, p_ref, slo_ref, shi_ref, t_ref, o_ref,
                   acc_ref, *, n_k: int):
    """One (bm, bn) output tile over planar-packed int4 codes.

    xlo_ref/xhi_ref: (bm, bkh) activation column halves
    p_ref: (bn, bkh) uint8 payload — low nibble = first-half col, high
           nibble = second-half col (planar layout, core/packing)
    slo_ref/shi_ref: (1, bkh) column-scale halves    t_ref: (1, bn)
    o_ref: (bm, bn) output    acc_ref: (bm, bn) f32 VMEM scratch
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    p = p_ref[...].astype(jnp.int32)
    z_lo = _sign_extend_nibble(p & 0xF)          # (bn, bkh) VPU unpack
    z_hi = _sign_extend_nibble((p >> 4) & 0xF)
    xs_lo = xlo_ref[...].astype(jnp.float32) * slo_ref[...].astype(jnp.float32)
    xs_hi = xhi_ref[...].astype(jnp.float32) * shi_ref[...].astype(jnp.float32)
    dims = (((1,), (1,)), ((), ()))
    acc_ref[...] += (
        jax.lax.dot_general(xs_lo, z_lo, dims,
                            preferred_element_type=jnp.float32)
        + jax.lax.dot_general(xs_hi, z_hi, dims,
                              preferred_element_type=jnp.float32))

    @pl.when(k == n_k - 1)
    def _store():
        o_ref[...] = (acc_ref[...] * t_ref[...].astype(jnp.float32)
                      ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_kh", "interpret",
                     "out_dtype"))
def dequant_matmul_packed_pallas(x_lo, x_hi, payload, s_lo, s_hi, row_scale,
                                 *, block_m: int = 128, block_n: int = 128,
                                 block_kh: int = 256, interpret: bool = False,
                                 out_dtype=jnp.float32):
    """Packed-int4 fused dequant-matmul (DESIGN.md §8).

    ``x_lo``/``x_hi`` (m, kh) are the first/second halves of the activation
    columns; ``payload`` (n, kh) the planar-packed codes; ``s_lo``/``s_hi``
    (kh,) the matching column-scale halves.  All dims must be multiples of
    the block sizes (ops.py splits, pads, and re-fuses).  HBM reads per
    output tile: bkh weight *bytes* per (bm, bn) step — half the int8
    kernel's, a quarter of bf16's.
    """
    m, kh = x_lo.shape
    n, kh2 = payload.shape
    assert x_hi.shape == (m, kh) and kh == kh2, (x_lo.shape, x_hi.shape,
                                                 payload.shape)
    assert m % block_m == 0 and n % block_n == 0 and kh % block_kh == 0, (
        (m, n, kh), (block_m, block_n, block_kh))
    n_k = kh // block_kh
    grid = (m // block_m, n // block_n, n_k)
    return pl.pallas_call(
        functools.partial(_packed_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_kh), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_m, block_kh), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_n, block_kh), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((1, block_kh), lambda i, j, kk: (0, kk)),
            pl.BlockSpec((1, block_kh), lambda i, j, kk: (0, kk)),
            pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x_lo, x_hi, payload, s_lo.reshape(1, kh), s_hi.reshape(1, kh),
      row_scale.reshape(1, n))
