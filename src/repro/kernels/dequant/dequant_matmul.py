"""Fused dequantize-matmul Pallas TPU kernels.

The serving hot spot of WaterSIC-quantized models: weights live in HBM as
int8 ZSIC codes Z (out, in) plus a fused per-column scale s = α⊙γ (the 16/n
overhead of Alg. 3) and per-row scale t (the 16/a overhead).  The effective
weight is  Ŵ[o, i] = t[o]·Z[o, i]·s[i]  and the layer computes

    out[b, o] = Σ_i x[b, i] · Ŵ[o, i]
              = t[o] · Σ_i (x[b, i]·s[i]) · Z[o, i]

Fusing the dequantization into the matmul means the bf16 weight matrix never
round-trips through HBM — at decode batch sizes the matmul is weight-bytes
bound, so int8 codes cut the dominant roofline term ~2× vs bf16, and the
sub-byte variants (``dequant_matmul_packed_pallas``) cut it further: the
kernel streams uint8 planar-packed codes from HBM and unpacks them in-VMEM
(shift/mask/sign-extend for int4/int2, bit-plane reassembly for int3, all
on the VPU) right before the MXU dots, so HBM only ever sees
``nbits/8`` bytes per weight (DESIGN.md §8).  The column scaling is applied
to the *activation tile* (n ops per tile instead of a·n), the row scaling
to the accumulator.

Grid: (M/bm, N/bn, K/bk), K innermost (sequential) with an f32 VMEM
accumulator; MXU dims (bm, bn, bk) are multiples of 128 by construction in
ops.py.  The packed kernel contracts over *byte* blocks: every planar
layout (core/packing) assigns byte j's G = 8/nbits codes (8 bit-planes for
int3) to columns j, j+K/G, …, so plane g of the payload block dots against
the g-th contiguous *group* of activation columns — G contiguous MXU dots,
no lane interleave.  ops.py reshapes x/s to (m, G, kg) so one 3-D block
spec carries all groups of a byte-block step.  Out-of-range escapes are
applied OUTSIDE the kernel as a sparse COO correction
(ops._apply_escapes), keeping the hot loop branch-free.

Payload blocks for int3/int2 carry a small plane axis ((bn, 3, bkg) /
(bn, 1, bkg)); on real TPUs the sublane dim of a uint8 tile is 32, so the
plane axis rides in one padded tile — acceptable because the payload block
is the *smallest* operand by construction (3/8 resp. 1/4 byte per code).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["dequant_matmul_pallas", "dequant_matmul_packed_pallas"]


def _kernel(x_ref, z_ref, s_ref, t_ref, o_ref, acc_ref, *, n_k: int):
    """One (bm, bn) output tile; accumulate over the K grid dimension.

    x_ref: (bm, bk) activations        s_ref: (1, bk) column scales (α⊙γ)
    z_ref: (bn, bk) int8 codes         t_ref: (1, bn) row scales
    o_ref: (bm, bn) output             acc_ref: (bm, bn) f32 VMEM scratch
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xs = x_ref[...].astype(jnp.float32) * s_ref[...].astype(jnp.float32)
    z = z_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        xs, z, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _store():
        o_ref[...] = (acc_ref[...] * t_ref[...].astype(jnp.float32)
                      ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "interpret",
                     "out_dtype"))
def dequant_matmul_pallas(x, z, col_scale, row_scale, *,
                          block_m: int = 128, block_n: int = 128,
                          block_k: int = 512, interpret: bool = False,
                          out_dtype=jnp.float32):
    """x (m, k) · dequant(z (n, k), s (k,), t (n,))ᵀ → (m, n).

    All dims must be multiples of the block sizes (ops.py pads).
    """
    m, k = x.shape
    n, k2 = z.shape
    assert k == k2, (x.shape, z.shape)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        (m, n, k), (block_m, block_n, block_k))
    n_k = k // block_k
    grid = (m // block_m, n // block_n, n_k)
    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_n, block_k), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((1, block_k), lambda i, j, kk: (0, kk)),
            pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x, z, col_scale.reshape(1, k), row_scale.reshape(1, n))


# ---------------------------------------------------------------------------
# Generalized packed kernel: int4 nibbles / int3 bit-planes / int2 fields
# ---------------------------------------------------------------------------

#: column groups per payload byte-column, by payload nbits
PLANE_GROUPS = {2: 4, 3: 8, 4: 2}


def _unpack_planes(p, nbits: int):
    """uint8 payload block → list of G (bn, bkg) f32 code planes.

    int4: two nibble fields (shift/mask/sign-extend); int2: four 2-bit
    fields (same, narrower); int3: three bit-plane bytes reassembled into
    eight biased codes (u = code + 4).  All pure VPU elementwise ops.
    """
    if nbits == 4:
        v = p.astype(jnp.int32)
        return [jnp.where(f > 7, f - 16, f).astype(jnp.float32)
                for f in ((v & 0xF), ((v >> 4) & 0xF))]
    if nbits == 2:
        v = p[:, 0, :].astype(jnp.int32)
        return [jnp.where(f > 1, f - 4, f).astype(jnp.float32)
                for f in (((v >> (2 * g)) & 0x3) for g in range(4))]
    assert nbits == 3, nbits
    b0 = p[:, 0, :].astype(jnp.int32)
    b1 = p[:, 1, :].astype(jnp.int32)
    b2 = p[:, 2, :].astype(jnp.int32)
    return [(((b0 >> g) & 1) | (((b1 >> g) & 1) << 1)
             | (((b2 >> g) & 1) << 2)).astype(jnp.float32) - 4.0
            for g in range(8)]


def _packed_kernel(xg_ref, p_ref, sg_ref, t_ref, o_ref, acc_ref, *,
                   n_k: int, nbits: int):
    """One (bm, bn) output tile over a planar sub-byte payload.

    xg_ref: (bm, G, bkg) activation column groups (G = PLANE_GROUPS[nbits])
    p_ref:  (bn, bkg) uint8 int4 payload, or (bn, 3, bkg) int3 bit-planes,
            or (bn, 1, bkg) int2 fields — plane g holds column group g
            (planar layouts, core/packing)
    sg_ref: (1, G, bkg) column-scale groups    t_ref: (1, bn)
    o_ref:  (bm, bn) output    acc_ref: (bm, bn) f32 VMEM scratch
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    planes = _unpack_planes(p_ref[...], nbits)     # G × (bn, bkg) VPU unpack
    dims = (((1,), (1,)), ((), ()))
    acc = acc_ref[...]
    for g, z in enumerate(planes):
        xs = (xg_ref[:, g, :].astype(jnp.float32)
              * sg_ref[:, g, :].astype(jnp.float32))
        acc += jax.lax.dot_general(xs, z, dims,
                                   preferred_element_type=jnp.float32)
    acc_ref[...] = acc

    @pl.when(k == n_k - 1)
    def _store():
        o_ref[...] = (acc_ref[...] * t_ref[...].astype(jnp.float32)
                      ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("nbits", "block_m", "block_n", "block_kg", "interpret",
                     "out_dtype"))
def dequant_matmul_packed_pallas(x_groups, payload, s_groups, row_scale, *,
                                 nbits: int = 4, block_m: int = 128,
                                 block_n: int = 128, block_kg: int = 256,
                                 interpret: bool = False,
                                 out_dtype=jnp.float32):
    """Generalized packed fused dequant-matmul (DESIGN.md §8).

    ``x_groups`` (m, G, kg) carries the activation columns pre-split into
    the G = 8/nbits planar groups (8 for int3) matching the payload layout;
    ``payload`` is (n, kg) uint8 for int4, (n, 3, kg) for int3 bit-planes,
    (n, 1, kg) for int2; ``s_groups`` (G, kg) the column-scale groups.
    All dims must be multiples of the block sizes (ops.py splits, pads,
    and re-fuses).  HBM reads per output tile: bkg weight *bytes* per
    (bm, bn) step carrying G·bkg codes — nbits/8 of a byte per weight.
    """
    g = PLANE_GROUPS[nbits]
    m, g2, kg = x_groups.shape
    n = payload.shape[0]
    assert g2 == g and payload.shape[-1] == kg, (x_groups.shape,
                                                 payload.shape, nbits)
    if nbits == 4:
        assert payload.ndim == 2, payload.shape
        p_spec = pl.BlockSpec((block_n, block_kg), lambda i, j, kk: (j, kk))
    else:
        planes = payload.shape[1]
        assert payload.ndim == 3 and planes == {3: 3, 2: 1}[nbits], \
            payload.shape
        p_spec = pl.BlockSpec((block_n, planes, block_kg),
                              lambda i, j, kk: (j, 0, kk))
    assert m % block_m == 0 and n % block_n == 0 and kg % block_kg == 0, (
        (m, n, kg), (block_m, block_n, block_kg))
    n_k = kg // block_kg
    grid = (m // block_m, n // block_n, n_k)
    return pl.pallas_call(
        functools.partial(_packed_kernel, n_k=n_k, nbits=nbits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, g, block_kg), lambda i, j, kk: (i, 0, kk)),
            p_spec,
            pl.BlockSpec((1, g, block_kg), lambda i, j, kk: (0, 0, kk)),
            pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(x_groups, payload, s_groups.reshape(1, g, kg),
      row_scale.reshape(1, n))
