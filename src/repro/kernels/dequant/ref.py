"""Pure-jnp oracles for the fused dequant-matmul kernels.

``dequant_matmul_ref`` materializes the f32 weight — the ground-truth
oracle.  ``unpack_payload_ref`` / ``dequant_matmul_packed_ref`` are the
XLA *reference twins* of the packed Pallas kernels: they unpack a planar
int4/int3/int2 payload in-graph (via the core/packing inverses, which the
packing round-trip tests pin) and run the scale-the-activations
formulation — bit-for-bit what the in-VMEM kernel unpack must reproduce,
which makes them the interpret-mode parity anchors for the
``packed-kernel-parity`` CI matrix.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.packing import (unpack_int2_planar_jnp,
                                unpack_int3_planar_jnp,
                                unpack_int4_planar_jnp)

__all__ = ["dequant_matmul_ref", "dequantize_ref", "unpack_payload_ref",
           "dequant_matmul_packed_ref", "dequantize_leaf_ref"]


def dequantize_ref(z, col_scale, row_scale, dtype=jnp.float32):
    """Ŵ[o, i] = t[o] · Z[o, i] · s[i]."""
    return (z.astype(dtype) * col_scale.astype(dtype)[None, :]
            * row_scale.astype(dtype)[:, None])


@jax.jit
def dequant_matmul_ref(x, z, col_scale, row_scale):
    """out = x @ Ŵᵀ with the weight materialized in f32 (the oracle)."""
    w_hat = dequantize_ref(z, col_scale, row_scale)
    return x.astype(jnp.float32) @ w_hat.T


def unpack_payload_ref(payload, nbits: int) -> jnp.ndarray:
    """Planar payload → sign-extended int8 codes (…, G·kg), by nbits."""
    if nbits == 4:
        return unpack_int4_planar_jnp(payload)
    if nbits == 3:
        return unpack_int3_planar_jnp(payload)
    if nbits == 2:
        return unpack_int2_planar_jnp(payload)
    raise ValueError(f"no packed payload for nbits={nbits}")


def _payload_nbits_ref(payload) -> int:
    """nbits off the planar payload shape (ops.payload_nbits's logic,
    duplicated locally: ops.py imports this module, not vice versa)."""
    if payload.ndim >= 3 and payload.shape[-2] == 3:
        return 3
    if payload.ndim >= 3 and payload.shape[-2] == 1:
        return 2
    return 4


def dequantize_leaf_ref(leaf, index=None):
    """Materialize one served leaf's EFFECTIVE f32 weight as (in, out).

    The quality observatory's probe twin (DESIGN.md §14): given any
    serving-tree leaf — raw fp array, int8/int4 code matrix, or a planar
    packed uint8 payload with escape-COO corrections — return the exact
    dense weight the serving matmul realizes, so measured output
    discrepancy ``‖x(Ŵ−W)‖²`` reconciles against the plan's predicted
    per-matrix distortion.  ``index`` selects one matrix out of a
    stacked leaf (the layer axis of a split tree).  k-sharded leaves are
    refused: probe on the unsharded tree (the mesh serves the same codes
    by construction — tests/test_mesh_serving.py pins bit-identity).

    Orientation note: int8/int4 code matrices are stored (…, in, out)
    with ``Ŵ[i,o] = s[i]·Z[i,o]·t[o]``; packed payloads store
    (…, out, [plane,] kg) with escapes indexed (row=out, col=in) — both
    normalize to the raw leaf's (in, out) here.
    """
    import numpy as np
    if not (isinstance(leaf, dict) and "codes" in leaf):
        w = np.asarray(jnp.asarray(leaf).astype(jnp.float32))
        return w if index is None else w[index]
    if "kshard" in leaf:
        raise ValueError("dequantize_leaf_ref: probe the unsharded tree, "
                         "not a k-sharded leaf")
    codes, s, t = leaf["codes"], leaf["s"], leaf["t"]
    esc = None
    if "esc_row" in leaf:
        esc = (leaf["esc_row"], leaf["esc_col"], leaf["esc_dval"])
    if index is not None:
        codes, s, t = codes[index], s[index], t[index]
        if esc is not None:
            esc = tuple(e[index] for e in esc)
    s = np.asarray(s, np.float32)
    t = np.asarray(t, np.float32)
    if s.ndim != 1:
        raise ValueError("dequantize_leaf_ref wants one matrix — pass "
                         f"index for stacked leaves (s shape {s.shape})")
    if codes.dtype == jnp.uint8:                       # packed planar
        nbits = _payload_nbits_ref(codes)
        z = np.asarray(unpack_payload_ref(jnp.asarray(codes), nbits),
                       np.float32)[..., :s.shape[0]]   # (out, in)
        if esc is not None and esc[0].shape[-1]:
            er = np.asarray(esc[0], np.int64)
            ec = np.asarray(esc[1], np.int64)
            ev = np.asarray(esc[2], np.float32)
            np.add.at(z, (er, ec), ev)                 # true − clipped code
        return (t[:, None] * z * s[None, :]).T         # → (in, out)
    zf = np.asarray(jnp.asarray(codes).astype(jnp.float32))  # (in, out)
    return s[:, None] * zf * t[None, :]


@functools.partial(jax.jit, static_argnames=("nbits",))
def dequant_matmul_packed_ref(x, payload, col_scale, row_scale, *,
                              nbits: int = 4):
    """XLA twin of the packed Pallas kernel (in-graph unpack, fused by XLA
    into the operand read).  ``x`` and ``col_scale`` must already span the
    packed width G·payload.shape[-1] (ops.py zero-pads; pad columns hold
    x = 0 so any pad-code value contributes nothing)."""
    z = unpack_payload_ref(payload, nbits)        # (n, G·kg), exact in f32
    xs = x.astype(jnp.float32) * col_scale.astype(jnp.float32)[None, :]
    acc = jax.lax.dot_general(xs, z.astype(jnp.float32),
                              (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    return acc * row_scale.astype(jnp.float32)[None, :]
