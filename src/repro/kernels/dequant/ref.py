"""Pure-jnp oracles for the fused dequant-matmul kernels.

``dequant_matmul_ref`` materializes the f32 weight — the ground-truth
oracle.  ``unpack_payload_ref`` / ``dequant_matmul_packed_ref`` are the
XLA *reference twins* of the packed Pallas kernels: they unpack a planar
int4/int3/int2 payload in-graph (via the core/packing inverses, which the
packing round-trip tests pin) and run the scale-the-activations
formulation — bit-for-bit what the in-VMEM kernel unpack must reproduce,
which makes them the interpret-mode parity anchors for the
``packed-kernel-parity`` CI matrix.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.packing import (unpack_int2_planar_jnp,
                                unpack_int3_planar_jnp,
                                unpack_int4_planar_jnp)

__all__ = ["dequant_matmul_ref", "dequantize_ref", "unpack_payload_ref",
           "dequant_matmul_packed_ref"]


def dequantize_ref(z, col_scale, row_scale, dtype=jnp.float32):
    """Ŵ[o, i] = t[o] · Z[o, i] · s[i]."""
    return (z.astype(dtype) * col_scale.astype(dtype)[None, :]
            * row_scale.astype(dtype)[:, None])


@jax.jit
def dequant_matmul_ref(x, z, col_scale, row_scale):
    """out = x @ Ŵᵀ with the weight materialized in f32 (the oracle)."""
    w_hat = dequantize_ref(z, col_scale, row_scale)
    return x.astype(jnp.float32) @ w_hat.T


def unpack_payload_ref(payload, nbits: int) -> jnp.ndarray:
    """Planar payload → sign-extended int8 codes (…, G·kg), by nbits."""
    if nbits == 4:
        return unpack_int4_planar_jnp(payload)
    if nbits == 3:
        return unpack_int3_planar_jnp(payload)
    if nbits == 2:
        return unpack_int2_planar_jnp(payload)
    raise ValueError(f"no packed payload for nbits={nbits}")


@functools.partial(jax.jit, static_argnames=("nbits",))
def dequant_matmul_packed_ref(x, payload, col_scale, row_scale, *,
                              nbits: int = 4):
    """XLA twin of the packed Pallas kernel (in-graph unpack, fused by XLA
    into the operand read).  ``x`` and ``col_scale`` must already span the
    packed width G·payload.shape[-1] (ops.py zero-pads; pad columns hold
    x = 0 so any pad-code value contributes nothing)."""
    z = unpack_payload_ref(payload, nbits)        # (n, G·kg), exact in f32
    xs = x.astype(jnp.float32) * col_scale.astype(jnp.float32)[None, :]
    acc = jax.lax.dot_general(xs, z.astype(jnp.float32),
                              (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    return acc * row_scale.astype(jnp.float32)[None, :]
