"""Pure-jnp oracle for the fused dequant-matmul kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["dequant_matmul_ref", "dequantize_ref"]


def dequantize_ref(z, col_scale, row_scale, dtype=jnp.float32):
    """Ŵ[o, i] = t[o] · Z[o, i] · s[i]."""
    return (z.astype(dtype) * col_scale.astype(dtype)[None, :]
            * row_scale.astype(dtype)[:, None])


@jax.jit
def dequant_matmul_ref(x, z, col_scale, row_scale):
    """out = x @ Ŵᵀ with the weight materialized in f32 (the oracle)."""
    w_hat = dequantize_ref(z, col_scale, row_scale)
    return x.astype(jnp.float32) @ w_hat.T
