"""Jit'd public wrapper for the fused dequant-matmul.

``dequant_matmul`` dispatches on the payload dtype: int8/int4 code matrices
go to the int8 kernel, uint8 planar-packed int4 payloads (two codes per
byte, core/packing) to the packed kernel.  It pads to MXU-aligned block
multiples (including the odd-in-features pad column of a packed payload),
dispatches to the Pallas kernels on TPU (or interpret mode when requested)
and to a fused-by-XLA path on CPU, slices the padding off, and applies the
sparse escape correction — out-of-range codes stored as a COO delta list —
outside the kernel (DESIGN.md §8).

``dequant_matmul_xla`` is the collective-friendly pure-XLA formulation used
inside pjit'd serve graphs (the dry-run path): XLA fuses the int8→f32 convert
+ scale into the matmul's operand read, preserving the HBM-bytes advantage
that the roofline analysis measures.  ``dequant_matmul_packed_xla`` is its
packed sibling (in-graph nibble unpack, fused by XLA).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.packing import (unpack_int3_planar_jnp,
                                unpack_int4_planar_jnp)
from .dequant_matmul import dequant_matmul_packed_pallas, dequant_matmul_pallas
from .ref import dequant_matmul_ref

__all__ = ["dequant_matmul", "dequant_matmul_packed", "dequant_matmul_xla",
           "dequant_matmul_packed_xla", "dequant_matmul_packed3",
           "dequant_matmul_packed3_xla"]


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _apply_escapes(out, x, col_scale, row_scale, escapes):
    """out[b, r] += x[b, c]·s[c]·dval·t[r] for each COO escape (r, c, dval).

    ``dval = true_code − clipped_code``, so the correction is exact on top
    of the clipped in-kernel body; duplicate rows accumulate (scatter-add).
    A zero-length COO (the common case) is a static no-op.
    """
    esc_row, esc_col, esc_dval = escapes
    if esc_row.shape[0] == 0:
        return out
    coef = (col_scale[esc_col].astype(jnp.float32)
            * esc_dval.astype(jnp.float32)
            * row_scale[esc_row].astype(jnp.float32))
    contrib = x[:, esc_col].astype(jnp.float32) * coef[None, :]
    return out.at[:, esc_row].add(contrib.astype(out.dtype))


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "prefer_pallas", "interpret"))
def dequant_matmul(x, z, col_scale, row_scale, *, escapes=None,
                   block_m: int = 128, block_n: int = 128,
                   block_k: int = 512, prefer_pallas: bool = True,
                   interpret: bool = False):
    """x (m, k) · dequant(z, s, t)ᵀ → (m, n), padding + escapes handled here.

    ``z`` int8 (n, k) selects the int8 kernel; ``z`` uint8 (n, ceil(k/2))
    selects the packed-int4 kernel (planar nibble layout); ``z`` uint8
    (n, 3, ceil(k/8)) — the bit-plane axis of static size 3 — selects the
    int3 path (DESIGN.md §10, XLA in-graph unpack).  ``escapes`` is an
    optional COO triple (rows, cols, dvals) applied after the kernel.
    """
    if z.dtype == jnp.uint8:
        if z.ndim == 3:
            return dequant_matmul_packed3(x, z, col_scale, row_scale,
                                          escapes=escapes)
        return dequant_matmul_packed(
            x, z, col_scale, row_scale, escapes=escapes, block_m=block_m,
            block_n=block_n, block_k=block_k, prefer_pallas=prefer_pallas,
            interpret=interpret)
    m, k = x.shape
    n = z.shape[0]
    on_tpu = jax.default_backend() == "tpu"
    if prefer_pallas and (on_tpu or interpret):
        block_k_eff = min(block_k, max(128, k))
        xp = _pad_to(_pad_to(x, block_m, 0), block_k_eff, 1)
        zp = _pad_to(_pad_to(z, block_n, 0), block_k_eff, 1)
        sp = _pad_to(col_scale, block_k_eff, 0)
        tp = _pad_to(row_scale, block_n, 0)
        out = dequant_matmul_pallas(
            xp, zp, sp, tp, block_m=block_m, block_n=block_n,
            block_k=block_k_eff, interpret=interpret or not on_tpu)[:m, :n]
    else:
        out = dequant_matmul_xla(x, z, col_scale, row_scale)
    if escapes is not None:
        out = _apply_escapes(out, x, col_scale, row_scale, escapes)
    return out


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "prefer_pallas", "interpret"))
def dequant_matmul_packed(x, payload, col_scale, row_scale, *, escapes=None,
                          block_m: int = 128, block_n: int = 128,
                          block_k: int = 512, prefer_pallas: bool = True,
                          interpret: bool = False):
    """Packed-int4 serving matmul: x (m, k) × planar payload (n, ceil(k/2)).

    Odd in-features are handled here: the payload's pad nibble column holds
    code 0, and x / col_scale are zero-padded to the packed width before the
    halves are split, so the pad contributes nothing.
    """
    m, k = x.shape
    n, kb = payload.shape
    k_even = 2 * kb
    assert k in (k_even, k_even - 1), (x.shape, payload.shape)
    xp = _pad_to(x, k_even, 1) if k < k_even else x
    sp = _pad_to(col_scale, k_even, 0) if k < k_even else col_scale
    on_tpu = jax.default_backend() == "tpu"
    if prefer_pallas and (on_tpu or interpret):
        kh = kb
        block_kh = min(block_k // 2, max(128, kh))
        x_lo = _pad_to(_pad_to(xp[:, :kh], block_m, 0), block_kh, 1)
        x_hi = _pad_to(_pad_to(xp[:, kh:], block_m, 0), block_kh, 1)
        pp = _pad_to(_pad_to(payload, block_n, 0), block_kh, 1)
        s_lo = _pad_to(sp[:kh], block_kh, 0)
        s_hi = _pad_to(sp[kh:], block_kh, 0)
        tp = _pad_to(row_scale, block_n, 0)
        out = dequant_matmul_packed_pallas(
            x_lo, x_hi, pp, s_lo, s_hi, tp, block_m=block_m,
            block_n=block_n, block_kh=block_kh,
            interpret=interpret or not on_tpu)[:m, :n]
    else:
        out = dequant_matmul_packed_xla(xp, payload, sp, row_scale)
    if escapes is not None:
        out = _apply_escapes(out, x, col_scale, row_scale, escapes)
    return out


@jax.jit
def dequant_matmul_xla(x, z, col_scale, row_scale):
    """Scale-the-activations formulation; XLA keeps weights int8 in HBM."""
    xs = x.astype(jnp.float32) * col_scale.astype(jnp.float32)[None, :]
    acc = jax.lax.dot_general(xs, z.astype(jnp.bfloat16).astype(jnp.float32),
                              (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    return acc * row_scale.astype(jnp.float32)[None, :]


@jax.jit
def dequant_matmul_packed3(x, payload, col_scale, row_scale, *,
                           escapes=None):
    """Int3 serving matmul: x (m, k) × bit-plane payload (n, 3, ceil(k/8)).

    The 8-group pad columns hold code 0 and x/col_scale are zero-padded to
    the packed width, so the pad contributes nothing.  Unpack is a handful
    of elementwise shift/masks that XLA fuses into the operand read (a
    dedicated Pallas int3 kernel is tracked future work — the payload
    format and escape contract here are what it will consume)."""
    m, k = x.shape
    n = payload.shape[0]
    k_packed = 8 * payload.shape[-1]
    assert k <= k_packed and k > k_packed - 8, (x.shape, payload.shape)
    xp = _pad_to(x, k_packed, 1) if k < k_packed else x
    sp = _pad_to(col_scale, k_packed, 0) if k < k_packed else col_scale
    out = dequant_matmul_packed3_xla(xp, payload, sp, row_scale)[:m, :n]
    if escapes is not None:
        out = _apply_escapes(out, x, col_scale, row_scale, escapes)
    return out


@jax.jit
def dequant_matmul_packed3_xla(x, payload, col_scale, row_scale):
    """Bit-plane path for XLA backends: in-graph int3 unpack (elementwise,
    fused) then the scale-the-activations formulation.  x and col_scale
    must already span the packed width 8·payload.shape[-1]."""
    z = unpack_int3_planar_jnp(payload)       # (n, 8·k8), exact in f32
    xs = x.astype(jnp.float32) * col_scale.astype(jnp.float32)[None, :]
    acc = jax.lax.dot_general(xs, z.astype(jnp.float32),
                              (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    return acc * row_scale.astype(jnp.float32)[None, :]


@jax.jit
def dequant_matmul_packed_xla(x, payload, col_scale, row_scale):
    """Packed path for XLA backends: in-graph nibble unpack (elementwise,
    fused into the operand read) then the int8 formulation.  x and
    col_scale must already span the packed width 2·payload.shape[1]."""
    z = unpack_int4_planar_jnp(payload)       # (n, 2·kb), exact in f32
    xs = x.astype(jnp.float32) * col_scale.astype(jnp.float32)[None, :]
    acc = jax.lax.dot_general(xs, z.astype(jnp.float32),
                              (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    return acc * row_scale.astype(jnp.float32)[None, :]
