"""Jit'd public wrapper for the fused dequant-matmul.

``dequant_matmul`` pads to MXU-aligned block multiples, dispatches to the
Pallas kernel on TPU (or interpret mode when requested) and to a fused-by-XLA
path on CPU, and slices the padding off.

``dequant_matmul_xla`` is the collective-friendly pure-XLA formulation used
inside pjit'd serve graphs (the dry-run path): XLA fuses the int8→f32 convert
+ scale into the matmul's operand read, preserving the HBM-bytes advantage
that the roofline analysis measures.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .dequant_matmul import dequant_matmul_pallas
from .ref import dequant_matmul_ref

__all__ = ["dequant_matmul", "dequant_matmul_xla"]


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "prefer_pallas", "interpret"))
def dequant_matmul(x, z, col_scale, row_scale, *, block_m: int = 128,
                   block_n: int = 128, block_k: int = 512,
                   prefer_pallas: bool = True, interpret: bool = False):
    """x (m, k) · dequant(z, s, t)ᵀ → (m, n), padding handled here."""
    m, k = x.shape
    n = z.shape[0]
    on_tpu = jax.default_backend() == "tpu"
    if prefer_pallas and (on_tpu or interpret):
        block_k_eff = min(block_k, max(128, k))
        xp = _pad_to(_pad_to(x, block_m, 0), block_k_eff, 1)
        zp = _pad_to(_pad_to(z, block_n, 0), block_k_eff, 1)
        sp = _pad_to(col_scale, block_k_eff, 0)
        tp = _pad_to(row_scale, block_n, 0)
        out = dequant_matmul_pallas(
            xp, zp, sp, tp, block_m=block_m, block_n=block_n,
            block_k=block_k_eff, interpret=interpret or not on_tpu)
        return out[:m, :n]
    return dequant_matmul_xla(x, z, col_scale, row_scale)


@jax.jit
def dequant_matmul_xla(x, z, col_scale, row_scale):
    """Scale-the-activations formulation; XLA keeps weights int8 in HBM."""
    xs = x.astype(jnp.float32) * col_scale.astype(jnp.float32)[None, :]
    acc = jax.lax.dot_general(xs, z.astype(jnp.bfloat16).astype(jnp.float32),
                              (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    return acc * row_scale.astype(jnp.float32)[None, :]
