"""Jit'd public wrapper for the fused dequant-matmul.

``dequant_matmul`` dispatches on the payload dtype and shape: int8 code
matrices go to the int8 kernel; uint8 payloads select the packed kernel
with the payload nbits read off the shape (core/packing layouts) —

    (n, ceil(k/2))        planar int4 nibbles          → nbits=4
    (n, 3, ceil(k/8))     int3 bit-planes              → nbits=3
    (n, 1, ceil(k/4))     planar int2 fields           → nbits=2

All three route through the SAME generalized Pallas kernel
(``dequant_matmul_packed_pallas``), which unpacks in-VMEM and contracts
plane-by-plane — the full 2/3/4-bit serving ladder runs in-kernel
(DESIGN.md §8).  This wrapper pads to MXU-aligned block multiples
(including the ragged-in-features pad columns of any packed payload),
splits the activation columns into the payload's planar groups,
dispatches to the Pallas kernels on TPU (or interpret mode when
requested) and to the XLA reference twins (kernels/dequant/ref.py) on
CPU, slices the padding off, and applies the sparse escape correction —
out-of-range codes stored as a COO delta list — outside the kernel.

``dequant_matmul_xla`` is the collective-friendly pure-XLA formulation used
inside pjit'd serve graphs (the dry-run path): XLA fuses the int8→f32
convert + scale into the matmul's operand read, preserving the HBM-bytes
advantage that the roofline analysis measures.  The packed XLA siblings
(``dequant_matmul_packed_xla`` / ``_packed3_xla`` / ``_packed2_xla``) are
thin aliases of the ref-twin with the payload nbits pinned.

Observability (DESIGN.md §11): the public entry points feed the
``repro_kernel_*`` metric families when ``repro.obs`` is enabled —
``repro_kernel_dispatch_total{format,path}`` counts Python-level kernel
entries (every eager call, and every jit TRACE when the matmul is
embedded in a larger jitted graph — re-dispatches of a cached executable
never re-enter Python, so in-graph use counts compilations, not steps).
Per-device-dispatch weight traffic is modeled at the ENGINE level, where
the step structure is visible: :func:`record_weight_traffic` adds a
param tree's per-format stored bytes (``weight_format_bytes`` — the same
``quant.leaf_inventory`` records benchmarks/check_bytes.py audits) to
``repro_kernel_hbm_bytes_total{format}`` once per forward dispatch, so
the counter reconciles EXACTLY with the byte-accounting gate
(benchmarks/check_obs.py asserts it).
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp

from repro import obs

from .dequant_matmul import (PLANE_GROUPS, dequant_matmul_packed_pallas,
                             dequant_matmul_pallas)
from .ref import dequant_matmul_packed_ref, dequant_matmul_ref

__all__ = ["dequant_matmul", "dequant_matmul_packed", "dequant_matmul_xla",
           "dequant_matmul_packed_xla", "dequant_matmul_packed3",
           "dequant_matmul_packed3_xla", "dequant_matmul_packed2",
           "dequant_matmul_packed2_xla", "dequant_matmul_sharded",
           "payload_nbits", "record_weight_traffic", "weight_format_bytes",
           "payload_checksums", "verify_payloads"]

#: payload nbits → the leaf-format label shared with quant.leaf_inventory
#: and benchmarks/check_bytes.py (one vocabulary across all three gates)
FORMAT_OF_NBITS = {8: "int8", 4: "packed-int4", 3: "packed-int3",
                   2: "packed-int2"}


def _count_dispatch(fmt: str, path: str) -> None:
    if obs.enabled():
        obs.counter("repro_kernel_dispatch_total", format=fmt,
                    path=path).inc()


def weight_format_bytes(tree) -> Dict[str, int]:
    """Serving format → total stored bytes over a param tree.

    Grouped from ``quant.leaf_inventory`` — the identical records the
    check_bytes.py CI gate audits — so engine-modeled HBM counters and
    the byte-accounting gate can never use two different byte models.
    """
    from repro.quant import leaf_inventory  # lazy: avoids an import cycle
    out: Dict[str, int] = {}
    for rec in leaf_inventory(tree):
        out[rec["format"]] = out.get(rec["format"], 0) + int(rec["bytes"])
    return out


def record_weight_traffic(format_bytes: Dict[str, int],
                          dispatches: int = 1) -> None:
    """Model ``dispatches`` forward passes' HBM weight reads.

    Every device dispatch (prefill chunk or decode step) streams the
    whole weight tree once, so each format's counter grows by its stored
    bytes × dispatches.  The serving engines call this per round/step
    with their cached :func:`weight_format_bytes`.
    """
    if not obs.enabled() or dispatches <= 0:
        return
    for fmt, nbytes in format_bytes.items():
        obs.counter("repro_kernel_hbm_bytes_total", format=fmt) \
            .inc(nbytes * dispatches)
        obs.counter("repro_kernel_weight_dispatch_total", format=fmt) \
            .inc(dispatches)


def _walk_qweights(tree):
    """(path-string, qweight-dict) pairs in quant.leaf_inventory's path
    vocabulary — integrity checksums, the inventory byte audit, and the
    chaos corruption log all key leaves the same way."""
    from repro.quant import is_qweight  # lazy: avoids an import cycle
    out = []

    def walk(node, path):
        if isinstance(node, dict):
            if is_qweight(node):
                out.append(("/".join(path), node))
                return
            for k, v in node.items():
                walk(v, path + (k,))
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(v, path + (str(i),))

    walk(tree, ())
    return out


def payload_checksums(tree) -> Dict[str, int]:
    """crc32 over every quantized leaf's code payload bytes (DESIGN.md §12).

    The checksum covers the ``codes`` array exactly as stored (packed
    uint8 payloads byte-verbatim, int8 code matrices likewise), keyed by
    the ``quant.leaf_inventory`` path — the integrity baseline the
    serving resilience layer verifies against between dispatches.  A
    single flipped payload byte changes the crc, so silent HBM/host
    corruption of served weights is detectable without dequantizing.
    """
    import zlib

    import numpy as np
    return {path: zlib.crc32(np.ascontiguousarray(
                np.asarray(leaf["codes"])).tobytes())
            for path, leaf in _walk_qweights(tree)}


def verify_payloads(tree, checksums: Dict[str, int]):
    """Paths whose payload crc32 no longer matches ``checksums``.

    Leaves added since the baseline (paths missing from ``checksums``)
    are reported too — a served tree must never grow unchecked payloads.
    Returns a sorted list; empty means the tree is intact.
    """
    current = payload_checksums(tree)
    return sorted(p for p, crc in current.items()
                  if checksums.get(p) != crc)


def payload_nbits(payload) -> int:
    """Payload nbits from the uint8 payload shape (see module docstring).

    The int3/int2 formats carry a plane axis of static size 3/1; a 2-D
    payload is the int4 nibble layout.  Weight matrices have ≥ 2 big dims
    (quant/qlinear `min_dim`), so a genuine out-features of 1 or 3 cannot
    alias the plane axis in practice.
    """
    if payload.ndim >= 3 and payload.shape[-2] == 3:
        return 3
    if payload.ndim >= 3 and payload.shape[-2] == 1:
        return 2
    return 4


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _apply_escapes(out, x, col_scale, row_scale, escapes):
    """out[b, r] += x[b, c]·s[c]·dval·t[r] for each COO escape (r, c, dval).

    ``dval = true_code − clipped_code``, so the correction is exact on top
    of the clipped in-kernel body; duplicate rows accumulate (scatter-add).
    A zero-length COO (the common case) is a static no-op.
    """
    esc_row, esc_col, esc_dval = escapes
    if esc_row.shape[0] == 0:
        return out
    coef = (col_scale[esc_col].astype(jnp.float32)
            * esc_dval.astype(jnp.float32)
            * row_scale[esc_row].astype(jnp.float32))
    contrib = x[:, esc_col].astype(jnp.float32) * coef[None, :]
    return out.at[:, esc_row].add(contrib.astype(out.dtype))


def dequant_matmul(x, z, col_scale, row_scale, *, escapes=None,
                   block_m: int = 128, block_n: int = 128,
                   block_k: int = 512, prefer_pallas: bool = True,
                   interpret: bool = False):
    """x (m, k) · dequant(z, s, t)ᵀ → (m, n), padding + escapes handled here.

    ``z`` int8 (n, k) selects the int8 kernel; a uint8 payload selects the
    packed kernel at the nbits its shape encodes (``payload_nbits``).
    ``escapes`` is an optional COO triple (rows, cols, dvals) applied after
    the kernel.  The eager entry bumps ``repro_kernel_dispatch_total``
    (format + kernel path) before handing off to the jitted body.
    """
    if z.dtype == jnp.uint8:
        return dequant_matmul_packed(
            x, z, col_scale, row_scale, nbits=payload_nbits(z),
            escapes=escapes, block_m=block_m, block_n=block_n,
            block_k=block_k, prefer_pallas=prefer_pallas,
            interpret=interpret)
    on_tpu = jax.default_backend() == "tpu"
    _count_dispatch("int8", "pallas" if prefer_pallas
                    and (on_tpu or interpret) else "xla")
    return _dequant_matmul_int8(
        x, z, col_scale, row_scale, escapes=escapes, block_m=block_m,
        block_n=block_n, block_k=block_k, prefer_pallas=prefer_pallas,
        interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "prefer_pallas", "interpret"))
def _dequant_matmul_int8(x, z, col_scale, row_scale, *, escapes=None,
                         block_m: int = 128, block_n: int = 128,
                         block_k: int = 512, prefer_pallas: bool = True,
                         interpret: bool = False):
    m, k = x.shape
    n = z.shape[0]
    on_tpu = jax.default_backend() == "tpu"
    if prefer_pallas and (on_tpu or interpret):
        block_k_eff = min(block_k, max(128, k))
        xp = _pad_to(_pad_to(x, block_m, 0), block_k_eff, 1)
        zp = _pad_to(_pad_to(z, block_n, 0), block_k_eff, 1)
        sp = _pad_to(col_scale, block_k_eff, 0)
        tp = _pad_to(row_scale, block_n, 0)
        out = dequant_matmul_pallas(
            xp, zp, sp, tp, block_m=block_m, block_n=block_n,
            block_k=block_k_eff, interpret=interpret or not on_tpu)[:m, :n]
    else:
        out = dequant_matmul_xla(x, z, col_scale, row_scale)
    if escapes is not None:
        out = _apply_escapes(out, x, col_scale, row_scale, escapes)
    return out


def dequant_matmul_packed(x, payload, col_scale, row_scale, *,
                          nbits: int = 4, escapes=None,
                          block_m: int = 128, block_n: int = 128,
                          block_k: int = 512, prefer_pallas: bool = True,
                          interpret: bool = False):
    """Packed serving matmul: x (m, k) × planar sub-byte payload.

    Ragged in-features are handled here: the payload's pad columns hold
    code 0 (or an arbitrary value — see below), and x / col_scale are
    zero-padded to the packed width G·kg before the planar groups are
    split, so every pad column multiplies an all-zero activation column
    and contributes nothing.  The same argument covers the block-align
    padding of the byte axis.  The eager entry bumps
    ``repro_kernel_dispatch_total`` before the jitted body.
    """
    on_tpu = jax.default_backend() == "tpu"
    _count_dispatch(FORMAT_OF_NBITS[nbits], "pallas" if prefer_pallas
                    and (on_tpu or interpret) else "ref")
    return _dequant_matmul_packed(
        x, payload, col_scale, row_scale, nbits=nbits, escapes=escapes,
        block_m=block_m, block_n=block_n, block_k=block_k,
        prefer_pallas=prefer_pallas, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("nbits", "block_m", "block_n",
                                             "block_k", "prefer_pallas",
                                             "interpret"))
def _dequant_matmul_packed(x, payload, col_scale, row_scale, *,
                           nbits: int = 4, escapes=None,
                           block_m: int = 128, block_n: int = 128,
                           block_k: int = 512, prefer_pallas: bool = True,
                           interpret: bool = False):
    g = PLANE_GROUPS[nbits]
    m, k = x.shape
    n, kg = payload.shape[0], payload.shape[-1]
    k_packed = g * kg
    assert k_packed - g < k <= k_packed, (x.shape, payload.shape, nbits)
    xp = _pad_to(x, k_packed, 1) if k < k_packed else x
    sp = _pad_to(col_scale, k_packed, 0) if k < k_packed else col_scale
    on_tpu = jax.default_backend() == "tpu"
    if prefer_pallas and (on_tpu or interpret):
        block_kg = min(max(128, block_k // g), max(128, kg))
        pp = _pad_to(_pad_to(payload, block_n, 0), block_kg, -1)
        # planar order is group-major, so the grouped view is a reshape —
        # but the byte-axis block pad must land INSIDE each group
        xg = _pad_to(_pad_to(xp, block_m, 0).reshape(-1, g, kg),
                     block_kg, -1)
        sg = _pad_to(sp.reshape(g, kg), block_kg, -1)
        tp = _pad_to(row_scale, block_n, 0)
        out = dequant_matmul_packed_pallas(
            xg, pp, sg, tp, nbits=nbits, block_m=block_m, block_n=block_n,
            block_kg=block_kg,
            interpret=interpret or not on_tpu)[:m, :n]
    else:
        out = dequant_matmul_packed_ref(xp, payload, sp, row_scale,
                                        nbits=nbits)
    if escapes is not None:
        out = _apply_escapes(out, x, col_scale, row_scale, escapes)
    return out


@jax.jit
def dequant_matmul_xla(x, z, col_scale, row_scale):
    """Scale-the-activations formulation; XLA keeps weights int8 in HBM."""
    xs = x.astype(jnp.float32) * col_scale.astype(jnp.float32)[None, :]
    acc = jax.lax.dot_general(xs, z.astype(jnp.bfloat16).astype(jnp.float32),
                              (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    return acc * row_scale.astype(jnp.float32)[None, :]


def _chain_sum(stacked):
    """Fixed-order chain sum over the leading axis: s0 + s1 + ... + s_{S-1}.

    The k-sharded matmul's psum epilogue.  An explicit add chain (not
    ``jnp.sum``) so BOTH the single-device oracle loop and the shard_map
    all-gather path reduce the per-shard partials through the identical
    op sequence — XLA never reassociates explicit float adds, which is
    what makes sharded streams bit-identical to the single-device engine.
    """
    acc = stacked[0]
    for i in range(1, stacked.shape[0]):
        acc = acc + stacked[i]
    return acc


def _shard_partial(x_loc, z_s, s_s, t, *, nbits, esc_s, kw):
    """One in-feature shard's (m, n) partial product.

    Per-shard zero-fill happened at pack time (``shard_planar_codes_jnp``:
    every shard's ragged tail carries code 0 / scale 0 at the END of its
    own block), so the single-shard packed path's local padding is exact —
    the global pad-to-``block_k_eff`` that put pad columns mid-matrix on
    all but the last shard never happens.
    """
    if z_s.dtype == jnp.uint8:
        return _dequant_matmul_packed(x_loc, z_s, s_s, t,
                                      nbits=nbits, escapes=esc_s, **kw)
    if z_s.dtype == jnp.int8:
        # scale-the-activations int8 partial; the shared row scale t is
        # applied once, after the chain sum (linear, so exactness holds)
        return (x_loc * s_s.astype(x_loc.dtype)) @ z_s.astype(x_loc.dtype)
    return x_loc @ z_s.astype(x_loc.dtype)   # raw fp shard (k_loc, n)


def dequant_matmul_sharded(x, z, col_scale=None, row_scale=None, *,
                           escapes=None, axis_name=None, shards=None,
                           **kw):
    """k-sharded matmul with an ordered psum epilogue (DESIGN.md §13).

    ``z`` stacks per-shard weight blocks along a leading shard axis:
    uint8 packed payloads ``(S, n, …kg_loc)`` (nbits read off the trailing
    planar shape as usual), int8 code matrices ``(S, k_loc, n)``, or raw
    fp blocks ``(S, k_loc, n)``.  ``col_scale`` is ``(S, k_loc)``,
    ``row_scale`` ``(n,)``, and ``escapes`` an optional COO triple whose
    arrays are ``(S, cap_loc)`` with *local* column indices.  ``x`` is the
    full ``(m, k)`` activation, zero-padded here to ``S·k_loc`` and split
    into contiguous per-shard blocks.

    Two execution modes, bit-identical by construction:

    * ``axis_name=None`` — the single-device oracle: loop the S shards
      locally, stack the partials, chain-sum.
    * ``axis_name="model"`` — inside a ``shard_map`` body: ``z`` et al.
      arrive with a local shard axis of size 1, this device computes ONLY
      its partial, then ``all_gather`` over the axis reproduces the same
      ``(S, m, n)`` stack the oracle built and the same chain sum runs.
      The gather moves the (m, n) *activation* partials — weights never
      cross devices on the decode path.
    """
    if axis_name is None:
        shards = z.shape[0]
    elif shards is None:
        raise ValueError("axis_name given but shards is None — the mesh "
                         "path needs the static shard count (the local z "
                         "block's shard axis is 1)")
    nbits = payload_nbits(z) if z.dtype == jnp.uint8 else None
    if z.dtype == jnp.uint8:
        k_loc = col_scale.shape[-1]
        _count_dispatch(FORMAT_OF_NBITS[nbits], "kshard")
    elif z.dtype == jnp.int8:
        k_loc = z.shape[-2]
        _count_dispatch("int8", "kshard")
    else:
        k_loc = z.shape[-2]
    m, k = x.shape
    total = shards * k_loc
    xp = _pad_to(x, total, 1) if k < total else x
    xg = xp.reshape(m, shards, k_loc)

    def esc_at(i):
        if escapes is None:
            return None
        er, ec, ev = escapes
        return (er[i], ec[i], ev[i])

    if axis_name is None:
        partials = [
            _shard_partial(xg[:, s, :], z[s],
                           None if col_scale is None else col_scale[s],
                           row_scale, nbits=nbits, esc_s=esc_at(s), kw=kw)
            for s in range(shards)]
        stacked = jnp.stack(partials, axis=0)
    else:
        idx = jax.lax.axis_index(axis_name)
        x_loc = jax.lax.dynamic_index_in_dim(xg, idx, 1, keepdims=False)
        partial = _shard_partial(
            x_loc, z[0], None if col_scale is None else col_scale[0],
            row_scale, nbits=nbits, esc_s=esc_at(0), kw=kw)
        stacked = jax.lax.all_gather(partial, axis_name, axis=0,
                                     tiled=False)
    out = _chain_sum(stacked)
    if z.dtype == jnp.int8:
        out = out * row_scale.astype(out.dtype)
    return out


def dequant_matmul_packed3(x, payload, col_scale, row_scale, *,
                           escapes=None, **kw):
    """Int3 serving matmul: x (m, k) × bit-plane payload (n, 3, ceil(k/8)),
    through the generalized in-kernel bit-plane unpack (DESIGN.md §8)."""
    return dequant_matmul_packed(x, payload, col_scale, row_scale,
                                 nbits=3, escapes=escapes, **kw)


def dequant_matmul_packed2(x, payload, col_scale, row_scale, *,
                           escapes=None, **kw):
    """Int2 serving matmul: x (m, k) × planar field payload
    (n, 1, ceil(k/4)) — ~0.25 B/weight of HBM traffic + escapes."""
    return dequant_matmul_packed(x, payload, col_scale, row_scale,
                                 nbits=2, escapes=escapes, **kw)


def dequant_matmul_packed_xla(x, payload, col_scale, row_scale):
    """Int4 XLA twin (in-graph nibble unpack, fused by XLA).  x and
    col_scale must already span the packed width 2·payload.shape[-1]."""
    return dequant_matmul_packed_ref(x, payload, col_scale, row_scale,
                                     nbits=4)


def dequant_matmul_packed3_xla(x, payload, col_scale, row_scale):
    """Int3 XLA twin (in-graph bit-plane unpack); packed width 8·kg."""
    return dequant_matmul_packed_ref(x, payload, col_scale, row_scale,
                                     nbits=3)


def dequant_matmul_packed2_xla(x, payload, col_scale, row_scale):
    """Int2 XLA twin (in-graph field unpack); packed width 4·kg."""
    return dequant_matmul_packed_ref(x, payload, col_scale, row_scale,
                                     nbits=2)
