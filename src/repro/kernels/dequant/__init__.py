from .dequant_matmul import dequant_matmul_packed_pallas, dequant_matmul_pallas
from .ops import (dequant_matmul, dequant_matmul_packed,
                  dequant_matmul_packed2, dequant_matmul_packed2_xla,
                  dequant_matmul_packed3, dequant_matmul_packed3_xla,
                  dequant_matmul_packed_xla, dequant_matmul_sharded,
                  dequant_matmul_xla, payload_nbits)
from .ref import (dequant_matmul_packed_ref, dequant_matmul_ref,
                  dequantize_leaf_ref, dequantize_ref, unpack_payload_ref)

__all__ = ["dequant_matmul_pallas", "dequant_matmul_packed_pallas",
           "dequant_matmul", "dequant_matmul_packed", "dequant_matmul_xla",
           "dequant_matmul_packed2", "dequant_matmul_packed2_xla",
           "dequant_matmul_packed3", "dequant_matmul_packed3_xla",
           "dequant_matmul_packed_xla", "dequant_matmul_packed_ref",
           "dequant_matmul_ref", "dequant_matmul_sharded",
           "dequantize_leaf_ref", "dequantize_ref",
           "unpack_payload_ref", "payload_nbits"]
