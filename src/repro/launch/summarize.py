"""Summarize dry-run JSONs into the EXPERIMENTS.md roofline tables,
QuantPlan artifacts into allocation reports (DESIGN.md §10), and
repro.obs JSONL metric logs into run reports (DESIGN.md §11).

    PYTHONPATH=src python -m repro.launch.summarize [--dir experiments/dryrun]
    PYTHONPATH=src python -m repro.launch.summarize --plan plan.json
    PYTHONPATH=src python -m repro.launch.summarize --metrics metrics.jsonl

Stdlib-only on purpose: all report paths read plain JSON, so ops tooling
can run this without the jax stack installed.
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def fmt_bytes(b):
    if b >= 1 << 30:
        return f"{b / (1 << 30):.2f}G"
    if b >= 1 << 20:
        return f"{b / (1 << 20):.1f}M"
    return f"{b / 1024:.0f}K"


def load_all(d):
    rows = []
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        rows.append(json.load(open(f)))
    return rows


def table(rows, mesh="single"):
    hdr = ("| arch | shape | st | flops/dev | bytes/dev | coll/dev | "
           "compute_s | memory_s | coll_s | dom | useful | RLfrac | "
           "mem/dev |")
    sep = "|" + "---|" * 13
    out = [hdr, sep]
    for r in rows:
        if r.get("mesh") != mesh or (r.get("wbits", 16) != 16):
            continue
        if r.get("status") == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | SKIP | "
                       f"{r.get('reason', '')[:40]} |" + " |" * 9)
            continue
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | "
                       f"{r.get('status', '?').upper()} |" + " |" * 10)
            continue
        rl = r["roofline"]
        mem = r.get("memory_analysis", {})
        peak = mem.get("argument_size_in_bytes", 0) \
            + mem.get("temp_size_in_bytes", 0)
        out.append(
            f"| {r['arch']} | {r['shape']} | ok "
            f"| {rl['hlo_flops_per_device']:.2e} "
            f"| {rl['hlo_bytes_per_device']:.2e} "
            f"| {rl['collective_bytes_per_device']:.2e} "
            f"| {rl['compute_s']:.4f} | {rl['memory_s']:.4f} "
            f"| {rl['collective_s']:.4f} | {rl['dominant'][:4]} "
            f"| {rl['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} "
            f"| {fmt_bytes(peak)} |")
    return "\n".join(out)


def _wmean(entries, field):
    tot = sum(e["out_features"] * e["in_features"] for e in entries)
    vals = [(e.get(field), e["out_features"] * e["in_features"])
            for e in entries]
    if any(v is None for v, _ in vals) or tot == 0:
        return None
    return sum(v * n for v, n in vals) / tot


def _layer_of(name):
    head = name.split("/", 1)[0]
    return int(head[1:]) if head.startswith("L") and head[1:].isdigit() \
        else -1


def plan_summary(d: dict, width: int = 40) -> str:
    """Render a QuantPlan JSON dict: realized bits/param vs target and the
    per-layer allocation histogram (param-weighted mean snapped bits)."""
    entries = d["entries"]
    budget = d["budget_bits_per_param"]
    planned = _wmean(entries, "snapped_bits")
    realized = _wmean(entries, "achieved_bits")
    out = [f"plan: {len(entries)} matrices, weighting={d['weighting']}, "
           f"schema v{d['schema_version']}"]
    line = (f"  budget {budget:.3f} bits/param | planned {planned:.3f}")
    if realized is not None:
        line += f" | realized {realized:.3f}"
    if d.get("budget_overrun"):
        line += "  [BUDGET OVERRUN — floors forced past the budget]"
    out.append(line)
    fmts = {}
    for e in entries:
        fmts[e["payload_bits"]] = fmts.get(e["payload_bits"], 0) + 1
    out.append("  payloads: " + ", ".join(
        f"int{b}×{c}" for b, c in sorted(fmts.items())))
    layers = {}
    for e in entries:
        n = e["out_features"] * e["in_features"]
        s = layers.setdefault(_layer_of(e["name"]), [0.0, 0.0])
        s[0] += e["snapped_bits"] * n
        s[1] += n
    out.append("  per-layer allocation (param-weighted mean snapped bits):")
    top = max((s[0] / s[1]) for s in layers.values()) if layers else 1.0
    for l, (num, den) in sorted(layers.items()):
        mean = num / den
        bar = "#" * max(1, int(round(width * mean / max(top, 1e-9))))
        tag = f"L{l}" if l >= 0 else "(?)"
        out.append(f"    {tag:>5} {mean:6.3f}b {bar}")
    return "\n".join(out)


def _fmt_val(name: str, v) -> str:
    """Seconds-suffixed metrics render in ms; everything else %g."""
    if v is None:
        return "-"
    if name.endswith("_seconds") and isinstance(v, (int, float)):
        return f"{v * 1e3:.2f}ms"
    return f"{v:g}" if isinstance(v, (int, float)) else str(v)


def _series_label(rec: dict) -> str:
    labels = rec.get("labels") or {}
    if not labels:
        return rec["name"]
    inner = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
    return f"{rec['name']}{{{inner}}}"


def _quality_sections(recs, width: int = 30):
    """Render the quality-observatory views of a metric log (DESIGN.md
    §14): drift verdicts, SLO burn rates, and per-layer/matrix output-MSE
    attribution. Empty list when the log has no quality series."""
    out = []
    drift = [r for r in recs if r["name"] == "repro_quality_drift_total"]
    if drift:
        out.append("  drift verdicts:")
        pad = max(len((r.get("labels") or {}).get("series", "?"))
                  for r in drift)
        for r in sorted(drift,
                        key=lambda r: (r.get("labels") or {}).get(
                            "series", "")):
            series = (r.get("labels") or {}).get("series", "?")
            n = int(r.get("value") or 0)
            verdict = f"DRIFT x{n}" if n else "ok"
            out.append(f"    {series:<{pad}}  {verdict}")
    burns = {(r.get("labels") or {}).get("slo", "?"): r.get("value")
             for r in recs if r["name"] == "repro_slo_burn_rate"}
    oks = {(r.get("labels") or {}).get("slo", "?"): r.get("value")
           for r in recs if r["name"] == "repro_slo_ok"}
    if burns:
        out.append("  slo burn rates (1.0 = budget consumed at the "
                   "sustainable rate):")
        pad = max(len(k) for k in burns)
        top = max([v or 0.0 for v in burns.values()] + [1.0])
        for slo in sorted(burns):
            burn = burns[slo] or 0.0
            ok = oks.get(slo, 1.0)
            bar = "#" * max(1, int(round(width * burn / top)))
            out.append(f"    {slo:<{pad}}  burn={burn:7.3f}  "
                       f"{'ok  ' if ok else 'VIOL'}  {bar}")
    attrib = [r for r in recs if r["name"] == "repro_quality_attrib"]
    if attrib:
        out.append("  quality attribution (layer-weighted output MSE, "
                   "largest = full bar):")
        layers = {}
        for r in attrib:
            labels = r.get("labels") or {}
            layers.setdefault(labels.get("layer", "?"), []).append(
                (labels.get("matrix", "?"), r.get("value") or 0.0))
        totals = {layer: sum(v for _, v in rows)
                  for layer, rows in layers.items()}
        top = max(totals.values(), default=0.0) or 1.0
        for layer in sorted(layers, key=lambda s: (len(s), s)):
            rows = sorted(layers[layer], key=lambda mv: -mv[1])
            bar = "#" * max(1, int(round(width * totals[layer] / top)))
            worst = rows[0][0] if rows else "?"
            out.append(f"    L{layer:>3}  total={totals[layer]:.3e}  "
                       f"worst={worst}  {bar}")
    return out


def metrics_summary(lines, width: int = 30) -> str:
    """Render a repro.obs JSONL metric log (DESIGN.md §11): counters and
    gauges as a value table, histograms with count/quantiles and a
    param-free #-bar over p50 (largest p50 = full width). Quality series
    (DESIGN.md §14) additionally render drift/SLO/attribution tables."""
    recs = [json.loads(ln) for ln in lines if ln.strip()]
    by_kind = {"counter": [], "gauge": [], "histogram": []}
    for r in recs:
        by_kind.setdefault(r.get("kind", "?"), []).append(r)
    out = [f"metrics: {len(recs)} series "
           f"({len(by_kind['counter'])} counters, "
           f"{len(by_kind['gauge'])} gauges, "
           f"{len(by_kind['histogram'])} histograms)"]
    for kind in ("counter", "gauge"):
        if not by_kind[kind]:
            continue
        out.append(f"  {kind}s:")
        pad = max(len(_series_label(r)) for r in by_kind[kind])
        for r in sorted(by_kind[kind], key=_series_label):
            out.append(f"    {_series_label(r):<{pad}}  "
                       f"{_fmt_val(r['name'], r['value'])}")
    hists = by_kind["histogram"]
    if hists:
        out.append("  histograms:")
        top = max((r["quantiles"].get("0.5") or 0) for r in hists) or 1.0
        pad = max(len(_series_label(r)) for r in hists)
        for r in sorted(hists, key=_series_label):
            q = r["quantiles"]
            p50 = q.get("0.5")
            bar = "#" * max(1, int(round(width * (p50 or 0) / top)))
            out.append(
                f"    {_series_label(r):<{pad}}  n={r['count']}"
                f" p50={_fmt_val(r['name'], p50)}"
                f" p90={_fmt_val(r['name'], q.get('0.9'))}"
                f" p99={_fmt_val(r['name'], q.get('0.99'))}"
                f" max={_fmt_val(r['name'], r.get('max'))}"
                f"{'' if r.get('exact', True) else ' ~'} {bar}")
    out.extend(_quality_sections(recs, width=width))
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--plan", default=None,
                    help="summarize a QuantPlan artifact instead of the "
                         "dry-run roofline tables")
    ap.add_argument("--metrics", default=None,
                    help="summarize a repro.obs JSONL metric log "
                         "(counters + histogram quantiles)")
    args = ap.parse_args(argv)
    if args.plan:
        with open(args.plan) as f:
            print(plan_summary(json.load(f)))
        return
    if args.metrics:
        with open(args.metrics) as f:
            print(metrics_summary(f))
        return
    rows = load_all(args.dir)
    print(table(rows, args.mesh))


if __name__ == "__main__":
    main()
