"""Summarize dry-run JSONs into the EXPERIMENTS.md roofline tables.

    PYTHONPATH=src python -m repro.launch.summarize [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def fmt_bytes(b):
    if b >= 1 << 30:
        return f"{b / (1 << 30):.2f}G"
    if b >= 1 << 20:
        return f"{b / (1 << 20):.1f}M"
    return f"{b / 1024:.0f}K"


def load_all(d):
    rows = []
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        rows.append(json.load(open(f)))
    return rows


def table(rows, mesh="single"):
    hdr = ("| arch | shape | st | flops/dev | bytes/dev | coll/dev | "
           "compute_s | memory_s | coll_s | dom | useful | RLfrac | "
           "mem/dev |")
    sep = "|" + "---|" * 13
    out = [hdr, sep]
    for r in rows:
        if r.get("mesh") != mesh or (r.get("wbits", 16) != 16):
            continue
        if r.get("status") == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | SKIP | "
                       f"{r.get('reason', '')[:40]} |" + " |" * 9)
            continue
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | "
                       f"{r.get('status', '?').upper()} |" + " |" * 10)
            continue
        rl = r["roofline"]
        mem = r.get("memory_analysis", {})
        peak = mem.get("argument_size_in_bytes", 0) \
            + mem.get("temp_size_in_bytes", 0)
        out.append(
            f"| {r['arch']} | {r['shape']} | ok "
            f"| {rl['hlo_flops_per_device']:.2e} "
            f"| {rl['hlo_bytes_per_device']:.2e} "
            f"| {rl['collective_bytes_per_device']:.2e} "
            f"| {rl['compute_s']:.4f} | {rl['memory_s']:.4f} "
            f"| {rl['collective_s']:.4f} | {rl['dominant'][:4]} "
            f"| {rl['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} "
            f"| {fmt_bytes(peak)} |")
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args(argv)
    rows = load_all(args.dir)
    print(table(rows, args.mesh))


if __name__ == "__main__":
    main()
