import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this proves, without hardware:
  * the sharding config is coherent (GSPMD partitions the whole step),
  * the program fits (memory_analysis),
  * and yields the roofline terms (cost_analysis + HLO collective bytes).

Usage:
    python -m repro.launch.dryrun --arch qwen2.5-32b --shape train_4k \
        --mesh single --out experiments/dryrun/
    python -m repro.launch.dryrun --all --mesh both   (sequential driver)

Writes one JSON per cell: experiments/dryrun/<arch>__<shape>__<mesh>.json
(existing files are skipped — the grid is resumable).
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config, input_specs, list_archs
from repro.configs.base import ArchConfig, ShapeSpec
from repro.dist.sharding import (batch_spec, spec_for_axes, use_mesh)
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import model_flops, report_from_artifacts
from repro.models import (decode_step, init_cache, init_params, loss_fn,
                          split_tree)
from repro.quant import quantize_params_tree
from repro.train import AdamWConfig, TrainState, adamw_init, make_train_step

__all__ = ["run_cell", "main"]


def _tree_specs(axes_tree, mesh):
    def to_spec(ax):
        return NamedSharding(mesh, spec_for_axes(ax))
    return jax.tree.map(to_spec, axes_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def _dp_if_divisible(dim: int, mesh):
    """DP axes tuple if the batch dim divides evenly, else None (replicate —
    e.g. long_500k's global_batch=1)."""
    dp = batch_spec(mesh)
    n = 1
    for a in dp:
        n *= mesh.shape[a]
    return dp if dim % n == 0 else None


def _batch_shardings(batch_sds, mesh):
    def shard(x):
        spec = [_dp_if_divisible(x.shape[0], mesh)] \
            + [None] * (len(x.shape) - 1)
        return NamedSharding(mesh, P(*spec))
    return jax.tree.map(shard, batch_sds)


def _abstract_params(cfg: ArchConfig, mesh, *, quantized: bool,
                     nbits: int = 8):
    px = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
    params_sds, axes = split_tree(px)
    specs = _tree_specs(axes, mesh)
    if quantized:
        params_sds = jax.eval_shape(
            lambda p: quantize_params_tree(p, nbits=nbits), params_sds)
        # code dicts inherit the original weight's sharding; scales replicate
        specs = _qspec_tree(params_sds, specs, mesh)
    return params_sds, specs


def _qspec_tree(params_sds, specs, mesh):
    """Align a spec tree with a params tree whose weights became dicts."""
    def walk(p, s):
        if isinstance(p, dict) and "codes" in p:
            base = s if not isinstance(s, dict) else s.get("codes")
            spec = base.spec if hasattr(base, "spec") else P()
            sub = list(spec) + [None] * (p["codes"].ndim - len(spec))
            return {
                "codes": NamedSharding(mesh, P(*sub[: p["codes"].ndim])),
                "s": NamedSharding(mesh, P(*sub[: p["s"].ndim])),
                "t": NamedSharding(
                    mesh, P(*(list(sub[: p["codes"].ndim - 2])
                              + [sub[p["codes"].ndim - 1]]))
                    if p["t"].ndim > 1 else P(sub[p["codes"].ndim - 1])),
            }
        if isinstance(p, dict):
            return {k: walk(p[k], s[k]) for k in p}
        if isinstance(p, (list, tuple)):
            return type(p)(walk(a, b) for a, b in zip(p, s))
        return s
    return walk(params_sds, specs)


def _cache_specs(cfg: ArchConfig, cache_sds, mesh):
    """PartitionSpecs for decode caches: batch over DP (when divisible),
    kv-heads / state heads over model (when divisible)."""

    from repro.opts import enabled as _opt
    kv_seq = _opt("kv_seq_shard")

    def mdl_if(dim):
        return "model" if dim % mesh.shape["model"] == 0 else None

    def by_shape(x):
        nd = len(x.shape)
        if nd == 0:
            return NamedSharding(mesh, P())
        dp = _dp_if_divisible(x.shape[1] if nd >= 2 else 1, mesh)
        if nd == 5:  # kv (L,B,buf,n_kv,hd) | rwkv wkv (L,B,H,dk,dv)
            head_axis = mdl_if(x.shape[3])
            if kv_seq and head_axis is None and mdl_if(x.shape[2]):
                # §Perf kv_seq_shard: fall back to sharding the seq dim
                return NamedSharding(mesh, P(None, dp, "model", None, None))
            return NamedSharding(mesh, P(None, dp, None, head_axis, None))
        if nd == 4:  # rglru conv state (L,B,cw,lru)
            return NamedSharding(mesh, P(None, dp, None, mdl_if(x.shape[3])))
        if nd == 3:  # shift states (L,B,d) / rec h (L,B,lru)
            return NamedSharding(mesh, P(None, dp, mdl_if(x.shape[2])))
        return NamedSharding(mesh, P(*([None] * nd)))

    return jax.tree.map(by_shape, cache_sds)


def _auto_micro(cfg: ArchConfig, shape: ShapeSpec, mesh) -> int:
    env = os.environ.get("REPRO_N_MICRO")
    if env:
        return int(env)
    if cfg.microbatch:
        return cfg.microbatch
    dp = 1
    for a in batch_spec(mesh):
        dp *= mesh.shape[a]
    per_dev = max(shape.global_batch // dp, 1)
    n_micro = min(per_dev, 16)
    while shape.global_batch % n_micro:
        n_micro -= 1
    return max(n_micro, 1)


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             wbits: int = 16, out_dir: str = "experiments/dryrun",
             force: bool = False, save_hlo: bool = False) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch}__{shape_name}__{mesh_kind}" + \
        (f"__w{wbits}" if wbits != 16 else "")
    out_path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.subquadratic:
        result = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                  "status": "skipped",
                  "reason": "full-attention arch: 500k KV decode out of "
                            "scope (DESIGN.md §5)"}
        with open(out_path, "w") as f:
            json.dump(result, f, indent=1)
        return result

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.size
    _HLO_DIR[0] = os.path.join(out_dir, tag + ".hlo.zz")
    t0 = time.time()
    try:
        with use_mesh(mesh):
            if shape.kind == "train":
                result = _lower_train(cfg, shape, mesh, mesh_kind)
            else:
                result = _lower_serve(cfg, shape, mesh, mesh_kind,
                                      prefill=(shape.kind == "prefill"),
                                      wbits=wbits)
    except Exception as e:  # noqa: BLE001 — recorded as cell failure
        result = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                  "status": "failed", "error": f"{type(e).__name__}: {e}",
                  "traceback": traceback.format_exc()[-4000:]}
    result.update({"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                   "chips": chips, "wbits": wbits,
                   "elapsed_s": round(time.time() - t0, 1)})
    with open(out_path, "w") as f:
        json.dump(result, f, indent=1, default=float)
    return result


_HLO_DIR = [None]  # set by run_cell so _collect can persist the HLO


def _collect(compiled, cfg, shape, mesh, mesh_kind, kind):
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    if _HLO_DIR[0]:
        import zlib
        with open(_HLO_DIR[0], "wb") as f:
            f.write(zlib.compress(hlo.encode(), 6))
    mem = compiled.memory_analysis()
    mem_info = {}
    peak = 0.0
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            mem_info[attr] = int(v)
    peak = mem_info.get("argument_size_in_bytes", 0) + \
        mem_info.get("temp_size_in_bytes", 0)
    tokens = shape.global_batch * (shape.seq_len if kind != "decode" else 1)
    mf = model_flops(cfg.active_param_count(), tokens,
                     "train" if kind == "train" else "serve")
    rep = report_from_artifacts(
        arch=cfg.name, shape=shape.name, mesh=mesh_kind, chips=mesh.size,
        cost=dict(cost), hlo_text=hlo, model_flops_total=mf,
        mem_peak_bytes=peak)
    return {
        "status": "ok",
        "kind": kind,
        "memory_analysis": mem_info,
        "cost_analysis": {k: float(v) for k, v in dict(cost).items()
                          if isinstance(v, (int, float))},
        "roofline": rep.to_json(),
        "dominant": rep.dominant,
        "bound_time_s": rep.bound_time_s,
        "roofline_fraction": rep.roofline_fraction,
        "hlo_bytes": len(hlo),
        "n_collectives": {k: v for k, v in
                          rep.collective_breakdown.items()},
    }


def _lower_train(cfg, shape, mesh, mesh_kind):
    params_sds, pspecs = _abstract_params(cfg, mesh, quantized=False)
    opt_sds = jax.eval_shape(adamw_init, params_sds)
    opt_specs = type(opt_sds)(
        step=NamedSharding(mesh, P()), m=pspecs, v=pspecs)
    state_sds = TrainState(params=params_sds, opt=opt_sds, err=None)
    state_specs = TrainState(params=pspecs, opt=opt_specs, err=None)
    batch_sds = input_specs(cfg, shape)
    batch_specs = _batch_shardings(batch_sds, mesh)
    n_micro = _auto_micro(cfg, shape, mesh)
    step = make_train_step(cfg, AdamWConfig(schedule=cfg.lr_schedule),
                           n_micro=n_micro)
    jitted = jax.jit(step,
                     in_shardings=(state_specs, batch_specs),
                     out_shardings=(state_specs, None),
                     donate_argnums=(0,))
    lowered = jitted.lower(state_sds, batch_sds)
    compiled = lowered.compile()
    out = _collect(compiled, cfg, shape, mesh, mesh_kind, "train")
    out["n_micro"] = n_micro
    return out


def _lower_serve(cfg, shape, mesh, mesh_kind, *, prefill: bool, wbits: int):
    params_sds, pspecs = _abstract_params(cfg, mesh,
                                          quantized=(wbits in (8, 4)),
                                          nbits=max(wbits, 4) if wbits < 16 else 8)
    if prefill:
        from repro.models import prefill as prefill_fn
        batch_sds = input_specs(cfg, shape)
        batch_specs = _batch_shardings(batch_sds, mesh)
        fn = lambda p, b: prefill_fn(cfg, p, b, max_len=shape.seq_len)
        jitted = jax.jit(fn, in_shardings=(pspecs, batch_specs))
        lowered = jitted.lower(params_sds, batch_sds)
        compiled = lowered.compile()
        return _collect(compiled, cfg, shape, mesh, mesh_kind, "prefill")
    # decode: one new token against a seq_len-deep cache/state
    cache_sds = jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len,
                           jnp.bfloat16))
    cache_specs = _cache_specs(cfg, cache_sds, mesh)
    tok_sds = input_specs(cfg, shape)
    tok_specs = _batch_shardings(tok_sds, mesh)
    fn = lambda p, c, t: decode_step(cfg, p, c, t["token"])
    jitted = jax.jit(fn, in_shardings=(pspecs, cache_specs, tok_specs),
                     out_shardings=(None, cache_specs),
                     donate_argnums=(1,))
    lowered = jitted.lower(params_sds, cache_sds, tok_sds)
    compiled = lowered.compile()
    return _collect(compiled, cfg, shape, mesh, mesh_kind, "decode")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--wbits", type=int, default=16,
                    choices=[16, 8, 4])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                r = run_cell(arch, shape, mesh_kind, wbits=args.wbits,
                             out_dir=args.out, force=args.force)
                status = r.get("status")
                dom = r.get("dominant", "-")
                print(f"{arch:24s} {shape:12s} {mesh_kind:6s} {status:8s} "
                      f"dominant={dom} t={r.get('elapsed_s', 0)}s",
                      flush=True)


if __name__ == "__main__":
    main()
