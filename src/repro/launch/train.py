"""Training driver: data pipeline + sharded train step + checkpoint/restart.

Runs real steps on the host mesh (CPU container: 1 device; production: the
same code under make_production_mesh on TPU).  Wires every fault-tolerance
piece: atomic checkpoints, restore-on-start, heartbeats, restart policy.

    PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b \
        --reduced --steps 50 --ckpt /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import DataConfig, global_batch_for_step
from repro.dist.checkpoint import latest_step, restore_checkpoint, \
    save_checkpoint
from repro.dist.fault import Heartbeat, StragglerMonitor
from repro.dist.sharding import use_mesh
from repro.launch.mesh import make_host_mesh
from repro.models import init_params, split_tree
from repro.train import AdamWConfig, TrainState, adamw_init, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--save-every", type=int, default=20)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh()
    opt_cfg = AdamWConfig(lr=args.lr, schedule=cfg.lr_schedule,
                          total_steps=args.steps,
                          warmup_steps=max(args.steps // 20, 1))
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch)

    with use_mesh(mesh):
        params, _ = split_tree(init_params(cfg, jax.random.PRNGKey(0)))
        state = TrainState(params=params, opt=adamw_init(params), err=None)
        start = 0
        if args.ckpt:
            last = latest_step(args.ckpt)
            if last is not None:
                state, _ = restore_checkpoint(args.ckpt, state, step=last)
                start = last
                print(f"restored step {start}")
        step_fn = jax.jit(make_train_step(cfg, opt_cfg,
                                          n_micro=args.n_micro))
        hb = Heartbeat(args.ckpt or "/tmp/hb", f"host{jax.process_index()}")
        mon = StragglerMonitor()
        for step in range(start, args.steps):
            t0 = time.time()
            batch = jax.tree.map(jnp.asarray,
                                 global_batch_for_step(dcfg, step))
            state, metrics = step_fn(state, batch)
            dt = time.time() - t0
            mon.observe(f"host{jax.process_index()}", dt)
            hb.beat(step)
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"{dt*1e3:.0f}ms", flush=True)
            if args.ckpt and ((step + 1) % args.save_every == 0
                              or step == args.steps - 1):
                save_checkpoint(args.ckpt, step + 1, state)
        return float(metrics["loss"])


if __name__ == "__main__":
    main()
