"""Serving driver: batched engine on the host mesh, optionally with
WaterSIC-quantized (int8-code) weights.

    PYTHONPATH=src python -m repro.launch.serve --arch minicpm-2b --reduced \
        --requests 6 --wbits 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.dist.sharding import use_mesh
from repro.launch.mesh import make_host_mesh
from repro.models import init_params, split_tree
from repro.quant import quantize_params_tree
from repro.serve import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--wbits", type=int, default=16, choices=[16, 8])
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh()
    rng = np.random.default_rng(0)
    with use_mesh(mesh):
        params, _ = split_tree(init_params(cfg, jax.random.PRNGKey(0)))
        if args.wbits == 8:
            params = quantize_params_tree(params)
            print("serving int8 WaterSIC-code weights")
        eng = ServeEngine(cfg, params, n_slots=args.slots,
                          max_len=args.prompt_len + args.max_new + 2)
        for i in range(args.requests):
            eng.submit(Request(
                rid=i,
                prompt=rng.integers(0, cfg.vocab,
                                    args.prompt_len).astype(np.int32),
                max_new_tokens=args.max_new))
        t0 = time.time()
        done = eng.run_until_done()
        dt = time.time() - t0
        total_tokens = sum(len(r.out_tokens) for r in done)
        print(f"served {len(done)} requests, {total_tokens} tokens "
              f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s)")
        for r in done[:4]:
            print(f"  rid={r.rid} out={r.out_tokens[:8]}")
        return done


if __name__ == "__main__":
    main()
