"""Serving driver: batched engine on the host mesh, optionally with
WaterSIC-quantized weights — int8 codes or any rung of the packed
sub-byte ladder (int4 nibbles / int3 bit-planes / int2 fields, planar
payload + escape COO, DESIGN.md §8) via ``--wbits {16,8,4,3,2}``.

``--continuous`` swaps the static-rounds scheduler for the
continuous-batching engine (per-slot decode streams with in-flight
admission, DESIGN.md §9); the static path stays the default and the
differential reference.

``--trace-out``/``--metrics-out``/``--events-out`` enable ``repro.obs``
(DESIGN.md §11) and export the run's Perfetto-loadable Chrome trace,
Prometheus text exposition, and JSONL metric log (the input to
``launch/summarize.py --metrics``).

Resilience flags (DESIGN.md §12) attach the serving-resilience layer:
``--deadline-s``/``--queue-cap`` bound latency and queue growth (dropped
requests are reported at exit), ``--retries`` arms transient-dispatch
retry, ``--integrity-every`` checksums+heals the quantized payloads,
``--degrade`` walks the int4→int3→int2 ladder under queue pressure, and
``--snapshot-dir``/``--snapshot-every`` write crash-recoverable engine
snapshots (``--resume`` restarts from the latest one).

    PYTHONPATH=src python -m repro.launch.serve --arch minicpm-2b --reduced \
        --requests 6 --wbits 4 --prefill-chunk 8 --continuous \
        --trace-out /tmp/serve_trace.json --metrics-out /tmp/serve.prom
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs import get_config
from repro.dist.fault import RestartPolicy
from repro.dist.sharding import use_mesh
from repro.launch.mesh import make_host_mesh
from repro.models import init_params, split_tree
from repro.quant import quantize_params_tree, qweight_bytes
from repro.serve import (ContinuousEngine, DegradePolicy, Request,
                         ResilienceConfig, ServeEngine, build_bit_ladder,
                         build_sharded_decode_fns, integer_allgathers,
                         lower_decode_hlo, shard_params_tree)


def add_obs_flags(ap: argparse.ArgumentParser) -> None:
    """The shared observability exports (serve + plan drivers)."""
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome trace-event JSON (Perfetto)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the Prometheus text exposition")
    ap.add_argument("--events-out", default=None, metavar="PATH",
                    help="write the JSONL metric log "
                         "(launch/summarize.py --metrics)")


def obs_setup(args) -> bool:
    """Enable repro.obs when any export flag is set; returns enablement."""
    if args.trace_out or args.metrics_out or args.events_out:
        obs.enable()
    return obs.enabled()


def obs_export(args) -> None:
    for path, write in ((args.trace_out, obs.write_trace),
                        (args.metrics_out, obs.write_prometheus),
                        (args.events_out, obs.write_jsonl)):
        if path:
            write(path)
            print(f"wrote {path}")


def add_resilience_flags(ap: argparse.ArgumentParser) -> None:
    """Serving-resilience knobs (shared with launch/chaos.py)."""
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request deadline (monotonic seconds from "
                         "arrival); expired requests are dropped, reported")
    ap.add_argument("--queue-cap", type=int, default=None,
                    help="bounded admission queue; submits past the cap "
                         "are shed")
    ap.add_argument("--retries", type=int, default=0,
                    help="transient-dispatch restart budget (0 = fail fast)")
    ap.add_argument("--retry-backoff-s", type=float, default=0.05)
    ap.add_argument("--integrity-every", type=int, default=None, metavar="N",
                    help="checksum the quantized payloads every N steps "
                         "and heal corruption from pristine copies")
    ap.add_argument("--degrade", action="store_true",
                    help="walk the serving bit ladder down under queue "
                         "pressure (and back up when it drains)")
    ap.add_argument("--degrade-high", type=int, default=8,
                    help="queue depth that counts as overload")
    ap.add_argument("--degrade-low", type=int, default=1,
                    help="queue depth that counts as drained")
    ap.add_argument("--snapshot-dir", default=None, metavar="DIR",
                    help="periodic engine snapshots via dist.checkpoint")
    ap.add_argument("--snapshot-every", type=int, default=16, metavar="N")
    ap.add_argument("--resume", action="store_true",
                    help="resume the continuous engine from the latest "
                         "snapshot in --snapshot-dir")


def resilience_from_args(args, params) -> ResilienceConfig | None:
    """Build the ResilienceConfig the flags describe (None if untouched).

    ``params`` is the engine's nominal serving tree — with ``--degrade``
    it becomes rung 0 of the ladder and the lower rungs are quantized
    down from it via the usual machinery.
    """
    degrade = None
    if args.degrade:
        # nominal tree first; lower rungs re-quantize the same leaves
        # down the ladder (already-int4 rung 0 keeps its packed leaves:
        # quantize_params_tree passes qweight nodes through unchanged)
        degrade = DegradePolicy(
            ladder=[("rung0", params)] + build_bit_ladder(params, (3, 2)),
            high_watermark=args.degrade_high,
            low_watermark=args.degrade_low)
    retry = RestartPolicy(max_restarts=args.retries,
                          backoff_base_s=args.retry_backoff_s,
                          reset_after=4) if args.retries else None
    if not any([args.deadline_s, args.queue_cap, retry,
                args.integrity_every, degrade, args.snapshot_dir]):
        return None
    return ResilienceConfig(
        queue_cap=args.queue_cap,
        default_deadline_s=args.deadline_s,
        retry=retry,
        integrity_every=args.integrity_every,
        degrade=degrade,
        snapshot_dir=args.snapshot_dir,
        snapshot_every=args.snapshot_every if args.snapshot_dir else None)


def _quantize_for_wbits(params, wbits: int):
    if wbits == 8:
        params = quantize_params_tree(params)
        print("serving int8 WaterSIC-code weights")
    elif wbits == 4:
        params = quantize_params_tree(params, nbits=4, packed=True)
        print("serving packed-int4 WaterSIC-code weights (planar nibble "
              "payload, fused unpack kernel)")
    elif wbits == 3:
        params = quantize_params_tree(params, nbits=3)
        print("serving int3 WaterSIC-code weights (bit-plane payload, "
              "in-kernel plane unpack)")
    elif wbits == 2:
        params = quantize_params_tree(params, nbits=2)
        print("serving int2 WaterSIC-code weights (planar 2-bit fields, "
              "4 codes/byte, in-kernel shift/mask unpack)")
    if wbits != 16:
        qb, fb = qweight_bytes(params)
        print(f"  param bytes {qb/1e6:.2f} MB vs bf16 {fb/1e6:.2f} MB "
              f"({fb/max(qb,1):.2f}x HBM win)")
    return params


def main_mesh(args, cfg):
    """Tensor-parallel k-sharded serving (DESIGN.md §13).

    Shards the serving tree over the full ``model`` axis, runs the SAME
    sharded tree through (a) the single-device oracle engine and (b) the
    mesh engine (whole decode step under one shard_map), and asserts the
    token streams are bit-identical.  ``--mesh-json`` dumps streams,
    per-leaf storage inventory, and the decode HLO's collective audit for
    the stdlib ``benchmarks/check_mesh.py`` gate.
    """
    import json

    from repro.models.transformer import init_cache
    from repro.quant import leaf_format_histogram, leaf_inventory

    # NOTE: the oracle runs OUTSIDE any use_mesh context — a partitioned
    # single-host graph could reassociate reductions; the oracle must be
    # the plain single-device program over the sharded tree.
    mesh = make_host_mesh(model_parallel=len(jax.devices()))
    shards = int(mesh.shape["model"])
    params, _ = split_tree(init_params(cfg, jax.random.PRNGKey(0)))
    params = _quantize_for_wbits(params, args.wbits)
    params = shard_params_tree(params, shards)
    qb, _ = qweight_bytes(params)
    print(f"mesh serving: {shards}-way in-feature sharding on {mesh} "
          f"({qb/1e6:.2f} MB stored, per-shard pad included)")
    max_len = args.prompt_len + args.max_new + 2
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, args.prompt_len).astype(np.int32)
               for _ in range(args.requests)]

    def serve(decode_fns, tag):
        kw = {}
        if decode_fns is not None:
            kw = {"decode_fn": decode_fns[0],
                  "decode_chunk_fn": decode_fns[1]}
        cls = ContinuousEngine if args.continuous else ServeEngine
        eng = cls(cfg, params, n_slots=args.slots, max_len=max_len,
                  prefill_chunk=args.prefill_chunk or None,
                  resilience=resilience_from_args(args, params), **kw)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p.copy(),
                               max_new_tokens=args.max_new))
        t0 = time.perf_counter()
        done = eng.run_until_done()
        dt = time.perf_counter() - t0
        toks = sum(len(r.out_tokens) for r in done)
        print(f"  {tag}: {len(done)} requests, {toks} tokens in {dt:.2f}s")
        return {r.rid: list(r.out_tokens) for r in done}

    oracle = serve(None, "single-device oracle")
    fns = build_sharded_decode_fns(cfg, params, mesh)
    meshed = serve(fns, f"{shards}-shard mesh")
    identical = oracle == meshed
    print(f"  streams bit-identical: {identical}")

    # collective audit: NO integer (weight-payload) all-gather may appear
    # on the compiled decode path — weights stay put, activations move
    cache = init_cache(cfg, args.slots, max_len, jnp.float32,
                       per_slot=args.continuous)
    tok = jnp.zeros((args.slots, 1), jnp.int32)
    hlo = lower_decode_hlo(cfg, params, mesh, cache, tok)
    bad = integer_allgathers(hlo)
    n_ag = sum("all-gather" in ln for ln in hlo.splitlines())
    print(f"  decode HLO: {n_ag} all-gather lines, "
          f"{len(bad)} integer-payload all-gathers")
    if args.mesh_json:
        payload = {
            "shards": shards, "wbits": args.wbits,
            "continuous": bool(args.continuous),
            "weight_bytes": int(qb),
            "weight_formats": leaf_format_histogram(params),
            "inventory": leaf_inventory(params),
            "streams_oracle": oracle, "streams_mesh": meshed,
            "identical": identical,
            "allgather_lines": int(n_ag),
            "integer_allgathers": bad,
        }
        with open(args.mesh_json, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
        print(f"wrote {args.mesh_json}")
    obs_export(args)
    if not identical:
        raise SystemExit("mesh streams diverged from the oracle")
    if bad:
        raise SystemExit("weight payload bytes crossed devices:\n"
                         + "\n".join(bad))
    return meshed


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--wbits", type=int, default=16, choices=[16, 8, 4, 3, 2])
    ap.add_argument("--prefill-chunk", type=int, default=8,
                    help="tokens per prefill device call (0 = per-token)")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching (per-slot decode streams, "
                         "in-flight admission) instead of static rounds")
    ap.add_argument("--mesh", action="store_true",
                    help="tensor-parallel k-sharded serving over the host "
                         "mesh's model axis, differentially checked "
                         "bit-identical against the single-device oracle")
    ap.add_argument("--mesh-json", default=None, metavar="PATH",
                    help="with --mesh: dump streams + storage inventory + "
                         "collective audit (input to check_mesh.py)")
    add_obs_flags(ap)
    add_resilience_flags(ap)
    args = ap.parse_args(argv)
    obs_setup(args)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.mesh:
        return main_mesh(args, cfg)
    mesh = make_host_mesh()
    rng = np.random.default_rng(0)
    with use_mesh(mesh):
        params, _ = split_tree(init_params(cfg, jax.random.PRNGKey(0)))
        params = _quantize_for_wbits(params, args.wbits)
        res = resilience_from_args(args, params)
        cls = ContinuousEngine if args.continuous else ServeEngine
        if args.resume:
            if not (args.continuous and args.snapshot_dir):
                ap.error("--resume needs --continuous and --snapshot-dir")
            eng = ContinuousEngine.resume(
                args.snapshot_dir, cfg, params,
                prefill_chunk=args.prefill_chunk or None, resilience=res)
            print(f"resumed from snapshot at tick {eng._tick} "
                  f"({eng.active_slots} slots live, "
                  f"{len(eng.queue)} queued)")
        else:
            eng = cls(cfg, params, n_slots=args.slots,
                      max_len=args.prompt_len + args.max_new + 2,
                      prefill_chunk=args.prefill_chunk or None,
                      resilience=res)
        for i in range(args.requests):
            eng.submit(Request(
                rid=i,
                prompt=rng.integers(0, cfg.vocab,
                                    args.prompt_len).astype(np.int32),
                max_new_tokens=args.max_new))
        t0 = time.perf_counter()
        done = eng.run_until_done()
        dt = time.perf_counter() - t0
        total_tokens = sum(len(r.out_tokens) for r in done)
        sched = "continuous" if args.continuous else "static"
        print(f"served {len(done)} requests, {total_tokens} tokens "
              f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s, {sched})")
        if args.continuous:
            print(f"  steps={len(eng.step_stats)} "
                  f"prefill={eng.prefill_calls} calls/"
                  f"{eng.prefill_s*1e3:.0f}ms "
                  f"decode={eng.decode_calls} calls/"
                  f"{eng.decode_s*1e3:.0f}ms")
        else:
            for st in eng.round_stats:
                print(f"  round: b={st.batch} plen={st.prompt_len} "
                      f"prefill={st.prefill_calls} calls/"
                      f"{st.prefill_s*1e3:.0f}ms "
                      f"decode={st.decode_calls} calls/"
                      f"{st.decode_s*1e3:.0f}ms new={st.new_tokens}")
        ttfts = sorted(r.ttft_s for r in done if r.ttft_s is not None)
        if ttfts:
            p50 = ttfts[len(ttfts) // 2]
            print(f"  TTFT p50={p50*1e3:.0f}ms max={ttfts[-1]*1e3:.0f}ms")
        if res is not None:
            for r in eng.dropped:
                print(f"  dropped rid={r.rid} ({r.drop_reason})")
            if eng.rung_history:
                print("  rungs: " + " -> ".join(
                    f"{name}@{tick}" for tick, name, _ in eng.rung_history))
        for r in done[:4]:
            print(f"  rid={r.rid} out={r.out_tokens[:8]}")
        obs_export(args)
        return done


if __name__ == "__main__":
    main()
