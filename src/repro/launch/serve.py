"""Serving driver: batched engine on the host mesh, optionally with
WaterSIC-quantized weights — int8 codes or any rung of the packed
sub-byte ladder (int4 nibbles / int3 bit-planes / int2 fields, planar
payload + escape COO, DESIGN.md §8) via ``--wbits {16,8,4,3,2}``.

``--continuous`` swaps the static-rounds scheduler for the
continuous-batching engine (per-slot decode streams with in-flight
admission, DESIGN.md §9); the static path stays the default and the
differential reference.

``--trace-out``/``--metrics-out``/``--events-out`` enable ``repro.obs``
(DESIGN.md §11) and export the run's Perfetto-loadable Chrome trace,
Prometheus text exposition, and JSONL metric log (the input to
``launch/summarize.py --metrics``).

    PYTHONPATH=src python -m repro.launch.serve --arch minicpm-2b --reduced \
        --requests 6 --wbits 4 --prefill-chunk 8 --continuous \
        --trace-out /tmp/serve_trace.json --metrics-out /tmp/serve.prom
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs import get_config
from repro.dist.sharding import use_mesh
from repro.launch.mesh import make_host_mesh
from repro.models import init_params, split_tree
from repro.quant import quantize_params_tree, qweight_bytes
from repro.serve import ContinuousEngine, Request, ServeEngine


def add_obs_flags(ap: argparse.ArgumentParser) -> None:
    """The shared observability exports (serve + plan drivers)."""
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome trace-event JSON (Perfetto)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the Prometheus text exposition")
    ap.add_argument("--events-out", default=None, metavar="PATH",
                    help="write the JSONL metric log "
                         "(launch/summarize.py --metrics)")


def obs_setup(args) -> bool:
    """Enable repro.obs when any export flag is set; returns enablement."""
    if args.trace_out or args.metrics_out or args.events_out:
        obs.enable()
    return obs.enabled()


def obs_export(args) -> None:
    for path, write in ((args.trace_out, obs.write_trace),
                        (args.metrics_out, obs.write_prometheus),
                        (args.events_out, obs.write_jsonl)):
        if path:
            write(path)
            print(f"wrote {path}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--wbits", type=int, default=16, choices=[16, 8, 4, 3, 2])
    ap.add_argument("--prefill-chunk", type=int, default=8,
                    help="tokens per prefill device call (0 = per-token)")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching (per-slot decode streams, "
                         "in-flight admission) instead of static rounds")
    add_obs_flags(ap)
    args = ap.parse_args(argv)
    obs_setup(args)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh()
    rng = np.random.default_rng(0)
    with use_mesh(mesh):
        params, _ = split_tree(init_params(cfg, jax.random.PRNGKey(0)))
        if args.wbits == 8:
            params = quantize_params_tree(params)
            print("serving int8 WaterSIC-code weights")
        elif args.wbits == 4:
            params = quantize_params_tree(params, nbits=4, packed=True)
            print("serving packed-int4 WaterSIC-code weights (planar nibble "
                  "payload, fused unpack kernel)")
        elif args.wbits == 3:
            params = quantize_params_tree(params, nbits=3)
            print("serving int3 WaterSIC-code weights (bit-plane payload, "
                  "in-kernel plane unpack)")
        elif args.wbits == 2:
            params = quantize_params_tree(params, nbits=2)
            print("serving int2 WaterSIC-code weights (planar 2-bit fields, "
                  "4 codes/byte, in-kernel shift/mask unpack)")
        if args.wbits != 16:
            qb, fb = qweight_bytes(params)
            print(f"  param bytes {qb/1e6:.2f} MB vs bf16 {fb/1e6:.2f} MB "
                  f"({fb/max(qb,1):.2f}x HBM win)")
        cls = ContinuousEngine if args.continuous else ServeEngine
        eng = cls(cfg, params, n_slots=args.slots,
                  max_len=args.prompt_len + args.max_new + 2,
                  prefill_chunk=args.prefill_chunk or None)
        for i in range(args.requests):
            eng.submit(Request(
                rid=i,
                prompt=rng.integers(0, cfg.vocab,
                                    args.prompt_len).astype(np.int32),
                max_new_tokens=args.max_new))
        t0 = time.perf_counter()
        done = eng.run_until_done()
        dt = time.perf_counter() - t0
        total_tokens = sum(len(r.out_tokens) for r in done)
        sched = "continuous" if args.continuous else "static"
        print(f"served {len(done)} requests, {total_tokens} tokens "
              f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s, {sched})")
        if args.continuous:
            print(f"  steps={len(eng.step_stats)} "
                  f"prefill={eng.prefill_calls} calls/"
                  f"{eng.prefill_s*1e3:.0f}ms "
                  f"decode={eng.decode_calls} calls/"
                  f"{eng.decode_s*1e3:.0f}ms")
        else:
            for st in eng.round_stats:
                print(f"  round: b={st.batch} plen={st.prompt_len} "
                      f"prefill={st.prefill_calls} calls/"
                      f"{st.prefill_s*1e3:.0f}ms "
                      f"decode={st.decode_calls} calls/"
                      f"{st.decode_s*1e3:.0f}ms new={st.new_tokens}")
        ttfts = sorted(r.ttft_s for r in done if r.ttft_s is not None)
        if ttfts:
            p50 = ttfts[len(ttfts) // 2]
            print(f"  TTFT p50={p50*1e3:.0f}ms max={ttfts[-1]*1e3:.0f}ms")
        for r in done[:4]:
            print(f"  rid={r.rid} out={r.out_tokens[:8]}")
        obs_export(args)
        return done


if __name__ == "__main__":
    main()
