"""Serving driver: batched engine on the host mesh, optionally with
WaterSIC-quantized weights — int8 codes or any rung of the packed
sub-byte ladder (int4 nibbles / int3 bit-planes / int2 fields, planar
payload + escape COO, DESIGN.md §8) via ``--wbits {16,8,4,3,2}``.

``--continuous`` swaps the static-rounds scheduler for the
continuous-batching engine (per-slot decode streams with in-flight
admission, DESIGN.md §9); the static path stays the default and the
differential reference.

``--trace-out``/``--metrics-out``/``--events-out`` enable ``repro.obs``
(DESIGN.md §11) and export the run's Perfetto-loadable Chrome trace,
Prometheus text exposition, and JSONL metric log (the input to
``launch/summarize.py --metrics``).

Resilience flags (DESIGN.md §12) attach the serving-resilience layer:
``--deadline-s``/``--queue-cap`` bound latency and queue growth (dropped
requests are reported at exit), ``--retries`` arms transient-dispatch
retry, ``--integrity-every`` checksums+heals the quantized payloads,
``--degrade`` walks the int4→int3→int2 ladder under queue pressure, and
``--snapshot-dir``/``--snapshot-every`` write crash-recoverable engine
snapshots (``--resume`` restarts from the latest one).

``--requant`` (DESIGN.md §15) serves from a waterfilled plan instead of
``--wbits`` and arms the live sense→decide→act loop: the quality
observatory streams Σ_X from traffic, and when divergence crosses
``--requant-limit`` the actuator re-solves the affected matrices over
the residual budget and hot-swaps the tree at a step boundary.  The
driver sends a drifted second traffic phase (repeated-token prompts) so
the loop demonstrably closes.  Requires ``--continuous``; incompatible
with ``--degrade`` (both subsystems hot-swap the served tree).

All engines are built from ONE :class:`repro.serve.EngineConfig` —
this driver is the reference for the config-first construction API.

    PYTHONPATH=src python -m repro.launch.serve --arch minicpm-2b --reduced \
        --requests 6 --wbits 4 --prefill-chunk 8 --continuous \
        --trace-out /tmp/serve_trace.json --metrics-out /tmp/serve.prom
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs import get_config
from repro.dist.fault import RestartPolicy
from repro.dist.sharding import use_mesh
from repro.launch.mesh import make_host_mesh
from repro.models import init_params, split_tree
from repro.quant import quantize_params_tree, qweight_bytes
from repro.serve import (ContinuousEngine, DegradePolicy, EngineConfig,
                         QualityConfig, Request, RequantConfig,
                         ResilienceConfig, ServeEngine, build_bit_ladder,
                         build_sharded_decode_fns, engine_from_plan,
                         integer_allgathers, lower_decode_hlo,
                         shard_params_tree, sigma_threshold_detectors)


def add_obs_flags(ap: argparse.ArgumentParser) -> None:
    """The shared observability exports (serve + plan drivers)."""
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome trace-event JSON (Perfetto)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the Prometheus text exposition")
    ap.add_argument("--events-out", default=None, metavar="PATH",
                    help="write the JSONL metric log "
                         "(launch/summarize.py --metrics)")


def obs_setup(args) -> bool:
    """Enable repro.obs when any export flag is set; returns enablement."""
    if args.trace_out or args.metrics_out or args.events_out:
        obs.enable()
    return obs.enabled()


def obs_export(args) -> None:
    for path, write in ((args.trace_out, obs.write_trace),
                        (args.metrics_out, obs.write_prometheus),
                        (args.events_out, obs.write_jsonl)):
        if path:
            write(path)
            print(f"wrote {path}")


def add_resilience_flags(ap: argparse.ArgumentParser) -> None:
    """Serving-resilience knobs (shared with launch/chaos.py)."""
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request deadline (monotonic seconds from "
                         "arrival); expired requests are dropped, reported")
    ap.add_argument("--queue-cap", type=int, default=None,
                    help="bounded admission queue; submits past the cap "
                         "are shed")
    ap.add_argument("--retries", type=int, default=0,
                    help="transient-dispatch restart budget (0 = fail fast)")
    ap.add_argument("--retry-backoff-s", type=float, default=0.05)
    ap.add_argument("--integrity-every", type=int, default=None, metavar="N",
                    help="checksum the quantized payloads every N steps "
                         "and heal corruption from pristine copies")
    ap.add_argument("--degrade", action="store_true",
                    help="walk the serving bit ladder down under queue "
                         "pressure (and back up when it drains)")
    ap.add_argument("--degrade-high", type=int, default=8,
                    help="queue depth that counts as overload")
    ap.add_argument("--degrade-low", type=int, default=1,
                    help="queue depth that counts as drained")
    ap.add_argument("--snapshot-dir", default=None, metavar="DIR",
                    help="periodic engine snapshots via dist.checkpoint")
    ap.add_argument("--snapshot-every", type=int, default=16, metavar="N")
    ap.add_argument("--resume", action="store_true",
                    help="resume the continuous engine from the latest "
                         "snapshot in --snapshot-dir")


def resilience_from_args(args, params) -> ResilienceConfig | None:
    """Build the ResilienceConfig the flags describe (None if untouched).

    ``params`` is the engine's nominal serving tree — with ``--degrade``
    it becomes rung 0 of the ladder and the lower rungs are quantized
    down from it via the usual machinery.
    """
    degrade = None
    if args.degrade:
        # nominal tree first; lower rungs re-quantize the same leaves
        # down the ladder (already-int4 rung 0 keeps its packed leaves:
        # quantize_params_tree passes qweight nodes through unchanged)
        degrade = DegradePolicy(
            ladder=[("rung0", params)] + build_bit_ladder(params, (3, 2)),
            high_watermark=args.degrade_high,
            low_watermark=args.degrade_low)
    retry = RestartPolicy(max_restarts=args.retries,
                          backoff_base_s=args.retry_backoff_s,
                          reset_after=4) if args.retries else None
    if not any([args.deadline_s, args.queue_cap, retry,
                args.integrity_every, degrade, args.snapshot_dir]):
        return None
    return ResilienceConfig(
        queue_cap=args.queue_cap,
        default_deadline_s=args.deadline_s,
        retry=retry,
        integrity_every=args.integrity_every,
        degrade=degrade,
        snapshot_dir=args.snapshot_dir,
        snapshot_every=args.snapshot_every if args.snapshot_dir else None)


def add_requant_flags(ap: argparse.ArgumentParser) -> None:
    """Live-requantization knobs (DESIGN.md §15)."""
    g = ap.add_argument_group("requant")
    g.add_argument("--requant", action="store_true",
                   help="serve from a waterfilled plan and re-plan + "
                        "hot-swap live when traffic Σ drifts (needs "
                        "--continuous; incompatible with --degrade)")
    g.add_argument("--requant-budget", type=float, default=4.0,
                   help="global bit budget per param for the plan")
    g.add_argument("--requant-calib", type=int, default=2, metavar="N",
                   help="synthetic calibration batches for the initial plan")
    g.add_argument("--requant-limit", type=float, default=2.0,
                   help="sigma_fro divergence threshold arming the drift "
                        "detectors (relative Frobenius shift)")
    g.add_argument("--requant-min-samples", type=int, default=32)
    g.add_argument("--requant-cooldown", type=int, default=8)
    g.add_argument("--requant-max", type=int, default=None, metavar="K",
                   help="cap on actuations (default unbounded)")
    g.add_argument("--requant-sigma-every", type=int, default=2,
                   help="shadow Σ_X sampling period (engine ticks)")


def requant_from_args(args) -> RequantConfig | None:
    if not args.requant:
        return None
    return RequantConfig(min_samples=args.requant_min_samples,
                         cooldown_steps=args.requant_cooldown,
                         max_actuations=args.requant_max)


def _requant_engine(args, cfg, params, econfig):
    """Plan-driven engine with the live requant loop armed (§15)."""
    from repro.plan import build_plan, collect_sigma_x, model_sensitivities
    from repro.quant.pipeline import matrix_tap_map

    rng = np.random.default_rng(1)
    calib = [rng.integers(0, cfg.vocab,
                          (2, max(args.prompt_len, 8))).astype(np.int32)
             for _ in range(args.requant_calib)]
    sens = model_sensitivities(cfg, params, calib, weighting="output")
    plan = build_plan(sens, args.requant_budget, weighting="output")
    acc = collect_sigma_x(cfg, params, calib)
    qc = QualityConfig(
        sigma_every=args.requant_sigma_every,
        detectors=sigma_threshold_detectors(
            matrix_tap_map(cfg, params), limit=args.requant_limit))
    eng = engine_from_plan(cfg, params, plan, calib=acc,
                           sensitivities=sens, config=econfig,
                           continuous=True, quality_config=qc)
    print(f"requant armed: {plan.planned_bits_per_param:.2f} b/param plan, "
          f"limit={args.requant_limit} "
          f"cooldown={args.requant_cooldown} "
          f"min_samples={args.requant_min_samples}")
    return eng, plan


def _quantize_for_wbits(params, wbits: int):
    if wbits == 8:
        params = quantize_params_tree(params)
        print("serving int8 WaterSIC-code weights")
    elif wbits == 4:
        params = quantize_params_tree(params, nbits=4, packed=True)
        print("serving packed-int4 WaterSIC-code weights (planar nibble "
              "payload, fused unpack kernel)")
    elif wbits == 3:
        params = quantize_params_tree(params, nbits=3)
        print("serving int3 WaterSIC-code weights (bit-plane payload, "
              "in-kernel plane unpack)")
    elif wbits == 2:
        params = quantize_params_tree(params, nbits=2)
        print("serving int2 WaterSIC-code weights (planar 2-bit fields, "
              "4 codes/byte, in-kernel shift/mask unpack)")
    if wbits != 16:
        qb, fb = qweight_bytes(params)
        print(f"  param bytes {qb/1e6:.2f} MB vs bf16 {fb/1e6:.2f} MB "
              f"({fb/max(qb,1):.2f}x HBM win)")
    return params


def main_mesh(args, cfg):
    """Tensor-parallel k-sharded serving (DESIGN.md §13).

    Shards the serving tree over the full ``model`` axis, runs the SAME
    sharded tree through (a) the single-device oracle engine and (b) the
    mesh engine (whole decode step under one shard_map), and asserts the
    token streams are bit-identical.  ``--mesh-json`` dumps streams,
    per-leaf storage inventory, and the decode HLO's collective audit for
    the stdlib ``benchmarks/check_mesh.py`` gate.
    """
    import json

    from repro.models.transformer import init_cache
    from repro.quant import leaf_format_histogram, leaf_inventory

    # NOTE: the oracle runs OUTSIDE any use_mesh context — a partitioned
    # single-host graph could reassociate reductions; the oracle must be
    # the plain single-device program over the sharded tree.
    mesh = make_host_mesh(model_parallel=len(jax.devices()))
    shards = int(mesh.shape["model"])
    params, _ = split_tree(init_params(cfg, jax.random.PRNGKey(0)))
    params = _quantize_for_wbits(params, args.wbits)
    params = shard_params_tree(params, shards)
    qb, _ = qweight_bytes(params)
    print(f"mesh serving: {shards}-way in-feature sharding on {mesh} "
          f"({qb/1e6:.2f} MB stored, per-shard pad included)")
    max_len = args.prompt_len + args.max_new + 2
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, args.prompt_len).astype(np.int32)
               for _ in range(args.requests)]

    base = EngineConfig(n_slots=args.slots, max_len=max_len,
                        prefill_chunk=args.prefill_chunk or None,
                        resilience=resilience_from_args(args, params))

    def serve(decode_fns, tag):
        econfig = base
        if decode_fns is not None:
            econfig = dataclasses.replace(base, decode_fn=decode_fns[0],
                                          decode_chunk_fn=decode_fns[1])
        cls = ContinuousEngine if args.continuous else ServeEngine
        eng = cls(cfg, params, config=econfig)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p.copy(),
                               max_new_tokens=args.max_new))
        t0 = time.perf_counter()
        done = eng.run_until_done()
        dt = time.perf_counter() - t0
        toks = sum(len(r.out_tokens) for r in done)
        print(f"  {tag}: {len(done)} requests, {toks} tokens in {dt:.2f}s")
        return {r.rid: list(r.out_tokens) for r in done}

    oracle = serve(None, "single-device oracle")
    fns = build_sharded_decode_fns(cfg, params, mesh)
    meshed = serve(fns, f"{shards}-shard mesh")
    identical = oracle == meshed
    print(f"  streams bit-identical: {identical}")

    # collective audit: NO integer (weight-payload) all-gather may appear
    # on the compiled decode path — weights stay put, activations move
    cache = init_cache(cfg, args.slots, max_len, jnp.float32,
                       per_slot=args.continuous)
    tok = jnp.zeros((args.slots, 1), jnp.int32)
    hlo = lower_decode_hlo(cfg, params, mesh, cache, tok)
    bad = integer_allgathers(hlo)
    n_ag = sum("all-gather" in ln for ln in hlo.splitlines())
    print(f"  decode HLO: {n_ag} all-gather lines, "
          f"{len(bad)} integer-payload all-gathers")
    if args.mesh_json:
        payload = {
            "shards": shards, "wbits": args.wbits,
            "continuous": bool(args.continuous),
            "weight_bytes": int(qb),
            "weight_formats": leaf_format_histogram(params),
            "inventory": leaf_inventory(params),
            "streams_oracle": oracle, "streams_mesh": meshed,
            "identical": identical,
            "allgather_lines": int(n_ag),
            "integer_allgathers": bad,
        }
        with open(args.mesh_json, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
        print(f"wrote {args.mesh_json}")
    obs_export(args)
    if not identical:
        raise SystemExit("mesh streams diverged from the oracle")
    if bad:
        raise SystemExit("weight payload bytes crossed devices:\n"
                         + "\n".join(bad))
    return meshed


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--wbits", type=int, default=16, choices=[16, 8, 4, 3, 2])
    ap.add_argument("--prefill-chunk", type=int, default=8,
                    help="tokens per prefill device call (0 = per-token)")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching (per-slot decode streams, "
                         "in-flight admission) instead of static rounds")
    ap.add_argument("--mesh", action="store_true",
                    help="tensor-parallel k-sharded serving over the host "
                         "mesh's model axis, differentially checked "
                         "bit-identical against the single-device oracle")
    ap.add_argument("--mesh-json", default=None, metavar="PATH",
                    help="with --mesh: dump streams + storage inventory + "
                         "collective audit (input to check_mesh.py)")
    add_obs_flags(ap)
    add_resilience_flags(ap)
    add_requant_flags(ap)
    args = ap.parse_args(argv)
    if args.requant:
        if not args.continuous:
            ap.error("--requant requires --continuous")
        if args.degrade:
            ap.error("--requant is incompatible with --degrade (both "
                     "hot-swap the served tree)")
        if args.mesh:
            ap.error("--requant does not support --mesh yet")
        if not obs_setup(args):
            obs.enable()   # the sense→act loop samples behind repro.obs
    else:
        obs_setup(args)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.mesh:
        return main_mesh(args, cfg)
    mesh = make_host_mesh()
    rng = np.random.default_rng(0)
    with use_mesh(mesh):
        params, _ = split_tree(init_params(cfg, jax.random.PRNGKey(0)))
        if not args.requant:
            params = _quantize_for_wbits(params, args.wbits)
        # the driver builds exactly ONE EngineConfig; every construction
        # path below (fresh, resumed, plan-driven) consumes it
        econfig = EngineConfig(
            n_slots=args.slots,
            max_len=args.prompt_len + args.max_new + 2,
            prefill_chunk=args.prefill_chunk or None,
            resilience=resilience_from_args(args, params),
            requant=requant_from_args(args))
        cls = ContinuousEngine if args.continuous else ServeEngine
        if args.resume:
            if not (args.continuous and args.snapshot_dir):
                ap.error("--resume needs --continuous and --snapshot-dir")
            eng = ContinuousEngine.resume(args.snapshot_dir, cfg, params,
                                          config=econfig)
            print(f"resumed from snapshot at tick {eng._tick} "
                  f"({eng.active_slots} slots live, "
                  f"{len(eng.queue)} queued)")
        elif args.requant:
            eng, _plan = _requant_engine(args, cfg, params, econfig)
        else:
            eng = cls(cfg, params, config=econfig)
        for i in range(args.requests):
            eng.submit(Request(
                rid=i,
                prompt=rng.integers(0, cfg.vocab,
                                    args.prompt_len).astype(np.int32),
                max_new_tokens=args.max_new))
        t0 = time.perf_counter()
        done = eng.run_until_done()
        if args.requant:
            # drifted second phase: repeated-token prompts collapse the
            # live Σ toward rank one; 2x the clean traffic so the drifted
            # samples dominate the streamed estimate and trip the
            # frobenius detectors
            for i in range(2 * args.requests):
                eng.submit(Request(
                    rid=args.requests + i,
                    prompt=np.full(args.prompt_len, 7, np.int32),
                    max_new_tokens=args.max_new))
            done += eng.run_until_done()
        dt = time.perf_counter() - t0
        total_tokens = sum(len(r.out_tokens) for r in done)
        sched = "continuous" if args.continuous else "static"
        print(f"served {len(done)} requests, {total_tokens} tokens "
              f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s, {sched})")
        if args.continuous:
            print(f"  steps={len(eng.step_stats)} "
                  f"prefill={eng.prefill_calls} calls/"
                  f"{eng.prefill_s*1e3:.0f}ms "
                  f"decode={eng.decode_calls} calls/"
                  f"{eng.decode_s*1e3:.0f}ms")
        else:
            for st in eng.round_stats:
                print(f"  round: b={st.batch} plen={st.prompt_len} "
                      f"prefill={st.prefill_calls} calls/"
                      f"{st.prefill_s*1e3:.0f}ms "
                      f"decode={st.decode_calls} calls/"
                      f"{st.decode_s*1e3:.0f}ms new={st.new_tokens}")
        ttfts = sorted(r.ttft_s for r in done if r.ttft_s is not None)
        if ttfts:
            p50 = ttfts[len(ttfts) // 2]
            print(f"  TTFT p50={p50*1e3:.0f}ms max={ttfts[-1]*1e3:.0f}ms")
        if eng.resilience is not None:
            for r in eng.dropped:
                print(f"  dropped rid={r.rid} ({r.drop_reason})")
            if eng.rung_history:
                print("  rungs: " + " -> ".join(
                    f"{name}@{tick}" for tick, name, _ in eng.rung_history))
        if args.requant:
            acts = eng.requant.actuations if eng.requant else []
            print(f"  requant actuations: {len(acts)}")
            for a in acts:
                moved = {n: (a['payload_before'][n], a['payload_after'][n])
                         for n in a['matrices']
                         if a['payload_before'][n] != a['payload_after'][n]}
                print(f"    tick={a['tick']} taps={','.join(a['taps'])} "
                      f"matrices={len(a['matrices'])} "
                      f"moved={moved or 'none'} "
                      f"replan={a['wall_s']*1e3:.0f}ms")
        for r in done[:4]:
            print(f"  rid={r.rid} out={r.out_tokens[:8]}")
        obs_export(args)
        return done


if __name__ == "__main__":
    main()
