"""Chaos-matrix driver: one fault class, one seed, three invariant runs
(DESIGN.md §12).

This is what the ``chaos-smoke`` CI job executes, once per (fault kind ×
seed) matrix cell (``CHAOS_KIND`` / ``CHAOS_SEED`` env vars, same pattern
as the packed-kernel-parity matrix).  Each invocation:

1. serves a seed-determined workload on a fault-free continuous engine
   (the reference streams);
2. replays the identical workload under an armed ``chaos.seeded_plan``
   with the resilience layer on, and requires every completed request's
   token stream to be bit-identical to the reference — dropped requests
   must be *reported*, never silently truncated (for the five canonical
   fault classes nothing may drop at all);
3. runs a snapshot → kill → resume cycle and requires the combined
   streams to be bit-identical to an uninterrupted run.

Results land in a JSON summary (stream-match booleans, the injection
log, an ``obs`` counter snapshot) plus the obs trace-event log;
``benchmarks/check_chaos.py`` — stdlib-only — reconciles the two and
gates CI.

    CHAOS_KIND=device-loss CHAOS_SEED=0 PYTHONPATH=src \
        python -m repro.launch.chaos --json-out /tmp/chaos.json \
        --trace-out /tmp/chaos_trace.json
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile

import jax
import numpy as np

from repro import chaos, obs
from repro.configs.base import ArchConfig
from repro.dist.fault import RestartPolicy
from repro.models import init_params, split_tree
from repro.quant import quantize_params_tree
from repro.serve import ContinuousEngine, Request, ResilienceConfig

# small-but-real serving config: quantized leaves (so corrupt-payload has
# payloads to flip), 2 slots (so admission bursts and evictions happen)
_CFG = ArchConfig(name="chaos", family="dense", n_layers=2, d_model=32,
                  n_heads=2, n_kv=2, d_ff=64, vocab=64, head_dim=16)
_N_REQ = 10
_BUDGET = 6
_MAX_LEN = 64


def _workload(seed: int):
    """The seed-determined request list (same for every run in a cell)."""
    rng = np.random.default_rng([int(seed), 0xFA17])
    return [Request(rid=i,
                    prompt=rng.integers(0, _CFG.vocab,
                                        4 + int(rng.integers(0, 3))
                                        ).astype(np.int32),
                    max_new_tokens=_BUDGET)
            for i in range(_N_REQ)]


def _params():
    base, _ = split_tree(init_params(_CFG, jax.random.PRNGKey(0)))
    # min_dim below the reduced model's widths: the corrupt-payload fault
    # needs real packed payloads in the tree to flip
    return quantize_params_tree(base, nbits=4, packed=True, min_dim=16)


def _resilience(**over) -> ResilienceConfig:
    kw = dict(
        retry=RestartPolicy(max_restarts=8, backoff_base_s=1e-3,
                            backoff_max_s=1e-2, reset_after=2),
        retry_sleep=lambda s: None,      # deterministic: no real waiting
        integrity_every=1,               # heal before the next dispatch
        # warmup 1 so an early injected slow step still flags; threshold
        # high enough that ordinary CI jitter (and the step-1 compile,
        # which IS 4x the later median) is the only other flag source
        slow_step_warmup=1, slow_step_threshold=4.0)
    kw.update(over)
    return ResilienceConfig(**kw)


def _run(params, seed: int, *, resilience=None, plan=None):
    eng = ContinuousEngine(_CFG, params, n_slots=2, max_len=_MAX_LEN,
                           prefill_chunk=4, resilience=resilience)
    for r in _workload(seed):
        eng.submit(r)
    if plan is not None:
        with chaos.active(plan) as rt:
            done = eng.run_until_done()
        return eng, done, rt
    return eng, eng.run_until_done(), None


def _streams(reqs):
    return {int(r.rid): [int(t) for t in r.out_tokens] for r in reqs}


def _resume_cycle(params, seed: int, reference, kill_after: int = 7):
    """Snapshot → kill → resume; True iff combined streams == reference."""
    with tempfile.TemporaryDirectory() as snap:
        eng = ContinuousEngine(
            _CFG, params, n_slots=2, max_len=_MAX_LEN, prefill_chunk=4,
            resilience=ResilienceConfig(snapshot_dir=snap, snapshot_every=3))
        for r in _workload(seed):
            eng.submit(r)
        for _ in range(kill_after):
            eng.step()
        delivered = _streams(r for r in eng.finished if r.done)
        del eng                          # the "kill": host state is gone
        eng2 = ContinuousEngine.resume(snap, _CFG, params, prefill_chunk=4)
        eng2.run_until_done()
        # requests that finished after the snapshot re-finish identically
        # on the resumed engine; the union must equal the reference
        combined = dict(delivered)
        combined.update(_streams(eng2.finished))
        return combined == reference, len(delivered), len(eng2.finished)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--kind", default=os.environ.get("CHAOS_KIND",
                                                     "device-loss"),
                    choices=list(chaos.FAULT_KINDS))
    ap.add_argument("--seed", type=int,
                    default=int(os.environ.get("CHAOS_SEED", "0")))
    ap.add_argument("--json-out", default=None, metavar="PATH")
    ap.add_argument("--trace-out", default=None, metavar="PATH")
    args = ap.parse_args(argv)

    params = _params()

    # 1. fault-free reference streams (no obs: keep the event log to the
    #    faulted run so check_chaos reconciles exactly one run's events)
    _, ref_done, _ = _run(params, args.seed)
    reference = _streams(ref_done)
    assert len(reference) == _N_REQ

    # 2. faulted run under the armed plan, obs on
    obs.reset()
    obs.enable()
    # delay_s is large vs the tiny per-step wall time so the slow-step
    # detector's 4x-median test has real margin; the schedule starts at
    # invocation 2 so step/decode 0-1 (jit compile) stay fault-free.
    # serve.admit fires only when slots free up (~N_REQ/n_slots times a
    # run), so the admission-failure horizon must stay inside that count.
    horizon, first = (4, 1) if args.kind == "admission-failure" else (20, 2)
    plan = chaos.seeded_plan(args.kind, args.seed, horizon=horizon,
                             n_faults=2, first=first, delay_s=0.25)
    eng, done, rt = _run(params, args.seed, resilience=_resilience(),
                         plan=plan)
    faulted = _streams(done)
    completed_match = all(faulted.get(rid) == toks
                          for rid, toks in reference.items()
                          if rid in faulted)
    summary = {
        "kind": args.kind,
        "seed": args.seed,
        "injected": rt.injected(),
        "injection_log": rt.log,
        "schedule": {s.site: list(s.at) for s in plan.specs},
        "completed": sorted(faulted),
        "streams_match": faulted == reference,
        "completed_match": completed_match,
        "dropped": [{"rid": r.rid, "reason": r.drop_reason}
                    for r in eng.dropped],
        "clock_skew_s": eng._clock_skew_s,
        "slow_steps": eng.slow_steps,
        "retries_used": (eng.resilience.retry.restarts_used
                         if eng.resilience.retry else 0),
        "counters": obs.counters_snapshot(),
    }

    # 3. snapshot → kill → resume (fault-free cycle, same workload)
    ok, pre, post = _resume_cycle(params, args.seed, reference)
    summary["resume_match"] = ok
    summary["resume_delivered_pre_kill"] = pre
    summary["resume_finished_post_resume"] = post

    if args.trace_out:
        obs.write_trace(args.trace_out)
        print(f"wrote {args.trace_out}")
    obs.disable()
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(summary, f, indent=1)
        print(f"wrote {args.json_out}")

    print(f"chaos[{args.kind} seed={args.seed}]: "
          f"{summary['injected']} injected, "
          f"streams_match={summary['streams_match']} "
          f"dropped={len(summary['dropped'])} "
          f"resume_match={summary['resume_match']}")
    return summary


if __name__ == "__main__":
    main()
