"""Recompute roofline fields in dry-run JSONs from the saved HLO artifacts.

The .hlo.zz files let us iterate on the cost parser (launch/hlo_cost.py)
without recompiling 80 cells:

    PYTHONPATH=src python -m repro.launch.reparse --dir experiments/dryrun
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import zlib

from repro.configs import SHAPES, get_config
from repro.launch.roofline import model_flops, report_from_artifacts


def reparse_file(jpath: str) -> bool:
    zpath = jpath.replace(".json", ".hlo.zz")
    if not os.path.exists(zpath):
        return False
    with open(jpath) as f:
        d = json.load(f)
    if d.get("status") != "ok":
        return False
    hlo = zlib.decompress(open(zpath, "rb").read()).decode()
    cfg = get_config(d["arch"])
    shape = SHAPES[d["shape"]]
    kind = d.get("kind", shape.kind)
    tokens = shape.global_batch * (shape.seq_len if kind != "decode" else 1)
    mf = model_flops(cfg.active_param_count(), tokens,
                     "train" if kind == "train" else "serve")
    mem = d.get("memory_analysis", {})
    peak = mem.get("argument_size_in_bytes", 0) \
        + mem.get("temp_size_in_bytes", 0)
    rep = report_from_artifacts(
        arch=d["arch"], shape=d["shape"], mesh=d["mesh"], chips=d["chips"],
        cost=d.get("cost_analysis", {}), hlo_text=hlo,
        model_flops_total=mf, mem_peak_bytes=peak)
    d["roofline"] = rep.to_json()
    d["dominant"] = rep.dominant
    d["bound_time_s"] = rep.bound_time_s
    d["roofline_fraction"] = rep.roofline_fraction
    d["n_collectives"] = dict(rep.collective_breakdown)
    with open(jpath, "w") as f:
        json.dump(d, f, indent=1, default=float)
    return True


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args(argv)
    n = 0
    for jpath in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        if reparse_file(jpath):
            n += 1
    print(f"reparsed {n} cells")


if __name__ == "__main__":
    main()
