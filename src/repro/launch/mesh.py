"""Production mesh builders (single-pod 16×16, multi-pod 2×16×16).

Functions (not module-level constants) so importing never touches JAX
device state — required because dryrun.py must set
XLA_FLAGS=--xla_force_host_platform_device_count before first JAX init.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 (data, model) single pod; 2×16×16 (pod, data, model) multi-pod.

    v5e: 256 chips/pod; the multi-pod mesh proves the "pod" axis shards
    (DCN-connected pods).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Tiny mesh over the actually-present devices (tests / examples)."""
    n = len(jax.devices())
    mp = min(model_parallel, n)
    return jax.make_mesh((n // mp, mp), ("data", "model"))
