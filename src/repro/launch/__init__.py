"""repro.launch — mesh builders, dry-run, roofline, train/serve drivers.

NOTE: do not import .dryrun from here — it sets XLA_FLAGS at import time and
must only be imported as the program entry point (python -m
repro.launch.dryrun).
"""
from .mesh import make_host_mesh, make_production_mesh

__all__ = ["make_host_mesh", "make_production_mesh"]
