"""While-loop-aware HLO cost accounting.

``xla::HloCostAnalysis`` (compiled.cost_analysis()) counts each while-loop
body ONCE, not × trip count (verified experimentally — scan vs unroll give
10× different flops for identical math).  Our programs are deeply scanned
(layers × microbatches × tokens), so raw numbers undercount by orders of
magnitude.

This module parses the post-partitioning HLO text (per-device program),
builds the computation call graph, extracts while trip counts from loop
conditions, and accumulates:

  * dot FLOPs            (2 · prod(result dims) · contracted size — the MXU
                          work; elementwise flops are ignored, <2% for these
                          graphs and noted in EXPERIMENTS.md),
  * collective bytes     (result-shape bytes of all-gather / all-reduce /
                          reduce-scatter / all-to-all / collective-permute),
  * weighted HBM bytes   (cost_analysis 'bytes accessed' scaled by the
                          flops multiplicity ratio — fusion-accurate byte
                          accounting per op is XLA-internal; the loop bodies
                          that dominate flops dominate bytes too).

Trip-count heuristic: the largest integer constant inside the loop's
condition computation (JAX scans lower to `lt(counter, N)`).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["parse_hlo_costs", "HloCosts"]

_COMP_HEADER = re.compile(
    r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*([^\s]+)\s+"
                    r"([a-z][\w\-]*)\(")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_WHILE = re.compile(r"while\(.*?\).*?(?:condition=%?([\w.\-]+)).*?"
                    r"(?:body=%?([\w.\-]+))", re.S)
_WHILE2 = re.compile(r"while\(.*?\).*?(?:body=%?([\w.\-]+)).*?"
                     r"(?:condition=%?([\w.\-]+))", re.S)
_CALL_TARGET = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_CONSTANT_INT = re.compile(r"constant\((\d+)\)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 0.5, "u4": 0.5, "pred": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _args_region(line: str, op: str) -> str:
    """The operand list of ``op`` in ``line`` — text between the opcode's
    opening paren and its balanced closing paren.  Needed because operand
    types may themselves contain parens/commas (tuple-typed operands)."""
    i = line.find(op + "(")
    if i < 0:
        return ""
    start = i + len(op) + 1
    depth = 1
    for k in range(start, len(line)):
        c = line[k]
        if c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
            if depth == 0:
                return line[start:k]
    return line[start:]


def _operand_names(argstr: str) -> List[str]:
    """Instruction names referenced in an operand list.

    Handles both HLO text dialects: verbose (``f32[64,128]{1,0} %name`` —
    names are %-prefixed; inline types carry commas, so naive comma
    splitting is wrong) and terse (bare ``name`` per comma slot).
    """
    names = re.findall(r"%([\w.\-]+)", argstr)
    if names:
        return names
    out = []
    for piece in argstr.split(","):
        tok = piece.strip().split(" ")[-1]
        if tok and "[" not in tok and "{" not in tok \
                and not tok[0].isdigit():
            out.append(tok)
    return out


def _instr_operands(line: str, op: str) -> List[str]:
    return _operand_names(_args_region(line, op))


def _first_shape(type_str: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE.search(type_str)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",") if d] if m.group(2) else []
    return m.group(1), dims


def _all_shape_bytes(type_str: str) -> float:
    total = 0.0
    for m in _SHAPE.finditer(type_str):
        dt = m.group(1)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class _Comp:
    name: str
    lines: List[str]
    shapes: Dict[str, str]              # instr name -> type string
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    edges: List[Tuple[str, float]] = dataclasses.field(default_factory=list)
    fusion_called: List[str] = dataclasses.field(default_factory=list)
    # edges: (callee, multiplier) — while bodies get trip count, calls get 1


@dataclasses.dataclass
class HloCosts:
    dot_flops: float
    hbm_bytes: float                   # weighted per-op operand+result bytes
    collective_bytes: float
    collective_breakdown: Dict[str, float]
    multiplicity_ratio: float          # weighted dot flops / unweighted
    n_whiles: int
    trip_counts: List[int]


def _split_computations(text: str) -> Dict[str, _Comp]:
    comps: Dict[str, _Comp] = {}
    cur: Optional[_Comp] = None
    for line in text.splitlines():
        m = _COMP_HEADER.match(line.strip()) if "{" in line else None
        if m and ("->" in line):
            cur = _Comp(name=m.group(1), lines=[], shapes={})
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        cur.lines.append(line)
        im = _INSTR.match(line)
        if im:
            cur.shapes[im.group(1)] = im.group(2)
    return comps


def _fusion_param_costs(callee: "_Comp") -> Dict[int, float]:
    """Per-parameter HBM traffic of a fusion computation.

    A parameter consumed ONLY through dynamic-slice (possibly via bitcast /
    reshape / copy aliases) moves just the sliced bytes — the pattern XLA
    emits for scan-input indexing.  Everything else counts full size.
    Memoized on the computation object.
    """
    memo = getattr(callee, "_param_costs", None)
    if memo is not None:
        return memo
    param_of: Dict[str, int] = {}      # instr name -> param index (aliases)
    full: Dict[int, float] = {}
    sliced: Dict[int, float] = {}
    touched_full: set = set()
    for line in callee.lines:
        im = _INSTR.match(line)
        if not im:
            continue
        name, type_str, op = im.groups()
        if op == "parameter":
            pm = re.search(r"parameter\((\d+)\)", line)
            if pm:
                idx = int(pm.group(1))
                param_of[name] = idx
                full[idx] = _all_shape_bytes(type_str)
            continue
        ops_list = _instr_operands(line, op)
        refs = [o for o in ops_list if o in param_of]
        if op in ("bitcast", "reshape", "copy", "transpose") and refs:
            param_of[name] = param_of[refs[0]]  # propagate alias
        elif op in ("dynamic-slice", "slice"):
            for o in refs:
                idx = param_of[o]
                sliced[idx] = sliced.get(idx, 0.0) \
                    + _all_shape_bytes(type_str)
        else:
            for o in refs:
                touched_full.add(param_of[o])
    costs = {}
    for idx, fb in full.items():
        if idx in touched_full or idx not in sliced:
            costs[idx] = fb
        else:
            costs[idx] = min(sliced[idx], fb)
    callee._param_costs = costs
    return costs


def _dus_root_update_bytes(comp: "_Comp") -> float:
    """If `comp` is an in-place buffer-update fusion (a dynamic-update-slice
    whose result shape equals the fusion result — possibly wrapped in
    converts, as the CPU backend's "wide" pass emits), return the bytes of
    the update operand (else 0).

    Rationale: XLA performs DUS in place; the whole-buffer convert chain
    the CPU emitter wraps around it does not exist on the TPU backend, so
    charging full-buffer traffic per scan step would wrongly dominate every
    scanned training graph (EXPERIMENTS.md §Dry-run accounting note).
    """
    root_shape = None
    for line in comp.lines:
        ls = line.strip()
        if ls.startswith("ROOT"):
            im = _INSTR.match(ls)
            if im:
                root_shape = _first_shape(im.group(2))
    if root_shape is None:
        return 0.0
    for line in comp.lines:
        ls = line.strip()
        if " dynamic-update-slice(" not in ls:
            continue
        im = _INSTR.match(ls)
        if not im:
            continue
        dus_shape = _first_shape(im.group(2))
        if dus_shape is None or dus_shape[1] != root_shape[1]:
            continue  # not the full-buffer in-place update
        ops = _instr_operands(ls, "dynamic-update-slice")
        if len(ops) > 1 and ops[1] in comp.shapes:
            return _all_shape_bytes(comp.shapes[ops[1]])
    return 0.0


def _trip_count(cond: _Comp) -> int:
    best = 1
    for line in cond.lines:
        for m in _CONSTANT_INT.finditer(line):
            best = max(best, int(m.group(1)))
    return best


def _analyze_comp(comp: _Comp, comps: Dict[str, _Comp]) -> None:
    body_text = "\n".join(comp.lines)
    # while edges: parse PER LINE (a computation can contain several whiles;
    # condition=/body= attribute order varies)
    seen_pairs = set()
    for line in comp.lines:
        if " while(" not in line:
            continue
        cm = re.search(r"condition=%?([\w.\-]+)", line)
        bm = re.search(r"body=%?([\w.\-]+)", line)
        if not (cm and bm):
            continue
        cond_name, body_name = cm.group(1), bm.group(1)
        key = (cond_name, body_name)
        if key in seen_pairs:
            continue
        seen_pairs.add(key)
        if cond_name in comps and body_name in comps:
            # newer XLA annotates the loop directly; else fall back to the
            # largest constant in the condition computation
            tm = re.search(r'known_trip_count[^\d]*(\d+)', line)
            trips = int(tm.group(1)) if tm else _trip_count(comps[cond_name])
            comp.edges.append((body_name, float(trips)))
            comp.edges.append((cond_name, float(trips)))
    # generic calls (fusions, custom calls, conditionals)
    for line in comp.lines:
        if "while(" in line:
            continue
        is_fusion = " fusion(" in line
        for m in _CALL_TARGET.finditer(line):
            if m.group(1) in comps:
                comp.edges.append((m.group(1), 1.0))
                if is_fusion:
                    comp.fusion_called.append(m.group(1))
    # per-op costs
    _NO_TRAFFIC = {"tuple", "get-tuple-element", "parameter", "bitcast",
                   "constant", "after-all", "partition-id", "replica-id",
                   "opt-barrier"}
    for line in comp.lines:
        im = _INSTR.match(line)
        if not im:
            continue
        name, type_str, op = im.groups()
        if op == "dot":
            flops = _dot_flops(line, type_str, comp)
            comp.dot_flops += flops
        elif any(op.startswith(c) for c in _COLLECTIVES):
            if op.endswith("-done"):
                continue
            kind = next(c for c in _COLLECTIVES if op.startswith(c))
            comp.coll_bytes[kind] = comp.coll_bytes.get(kind, 0.0) \
                + _all_shape_bytes(type_str)
        # HBM traffic model: result bytes + named-operand bytes for every
        # top-level op with real data movement (fusion internals are skipped
        # via the fusion_called mechanism below).  Op-specific rules:
        #   dynamic-slice/slice/gather: only the sliced result moves;
        #   dynamic-update-slice/scatter: 2× the update region (in-place);
        #   while/conditional: control only — bodies account themselves.
        if op in _NO_TRAFFIC or op in ("while", "conditional"):
            continue
        ops_list = _instr_operands(line, op)
        if op in ("dynamic-slice", "slice", "gather"):
            b = _all_shape_bytes(type_str)
        elif op in ("dynamic-update-slice", "scatter"):
            upd = ops_list[1] if len(ops_list) > 1 else None
            ub = _all_shape_bytes(comp.shapes.get(upd, "")) if upd else 0.0
            b = 2.0 * ub if ub else _all_shape_bytes(type_str)
        elif op == "fusion":
            # in-place DUS-root fusions (scan output stacking) move only the
            # updated slice; dynamic-slice-consumed params move slice bytes
            callee = None
            for m in _CALL_TARGET.finditer(line):
                if m.group(1) in comps:
                    callee = comps[m.group(1)]
                    break
            dus_ub = _dus_root_update_bytes(callee) if callee else 0.0
            if dus_ub:
                b = 2.0 * dus_ub
            elif callee is not None:
                pcosts = _fusion_param_costs(callee)
                b = _all_shape_bytes(type_str)
                for i, o in enumerate(ops_list):
                    if i in pcosts:
                        b += pcosts[i]
                    elif o in comp.shapes:
                        b += _all_shape_bytes(comp.shapes[o])
            else:
                b = _all_shape_bytes(type_str)
                for o in ops_list:
                    if o in comp.shapes:
                        b += _all_shape_bytes(comp.shapes[o])
        else:
            b = _all_shape_bytes(type_str)
            for o in ops_list:
                if o in comp.shapes:
                    b += _all_shape_bytes(comp.shapes[o])
        comp.hbm_bytes += b


def _dot_flops(line: str, result_type: str, comp: _Comp) -> float:
    rshape = _first_shape(result_type)
    if not rshape:
        return 0.0
    _, rdims = rshape
    out_elems = 1
    for d in rdims:
        out_elems *= d
    # contracted size from lhs operand shape + contracting dims
    cm = _CONTRACT.search(line)
    ops = _instr_operands(line, "dot")
    csize = 1
    if cm and ops:
        lhs = ops[0]
        lhs_type = comp.shapes.get(lhs, "")
        ls = _first_shape(lhs_type)
        if ls:
            for idx_s in cm.group(1).split(","):
                if idx_s:
                    i = int(idx_s)
                    if i < len(ls[1]):
                        csize *= ls[1][i]
    return 2.0 * out_elems * csize


def parse_hlo_costs(hlo_text: str) -> HloCosts:
    comps = _split_computations(hlo_text)
    for comp in comps.values():
        _analyze_comp(comp, comps)
    # find entry: computation not referenced by anyone, or named main
    referenced = {callee for c in comps.values() for callee, _ in c.edges}
    entry = None
    for name in comps:
        if name.startswith("main") or name.endswith("main"):
            entry = name
            break
    if entry is None:
        candidates = [n for n in comps if n not in referenced]
        entry = candidates[0] if candidates else next(iter(comps))
    # propagate weights through the call DAG
    weights: Dict[str, float] = {entry: 1.0}
    order = [entry]
    i = 0
    while i < len(order):
        cur = order[i]
        i += 1
        w = weights[cur]
        for callee, mult in comps[cur].edges:
            if callee not in weights:
                weights[callee] = 0.0
                order.append(callee)
            weights[callee] += w * mult
    # computations called ONLY from fusion ops don't touch HBM themselves
    fusion_only = set()
    all_fusion_callees = {c for comp in comps.values()
                          for c in comp.fusion_called}
    for name in all_fusion_callees:
        callers = [c for c in comps.values()
                   if any(cal == name for cal, _ in c.edges)]
        if callers and all(name in c.fusion_called for c in callers):
            fusion_only.add(name)
    total_dot = 0.0
    raw_dot = 0.0
    total_hbm = 0.0
    coll: Dict[str, float] = {}
    trips = []
    n_whiles = 0
    for name, comp in comps.items():
        w = weights.get(name, 0.0)
        total_dot += w * comp.dot_flops
        raw_dot += comp.dot_flops
        if name not in fusion_only:
            total_hbm += w * comp.hbm_bytes
        for kind, b in comp.coll_bytes.items():
            coll[kind] = coll.get(kind, 0.0) + w * b
        for callee, mult in comp.edges:
            if mult != 1.0:
                n_whiles += 1
                trips.append(int(mult))
    coll_total = sum(coll.values())
    return HloCosts(
        dot_flops=total_dot,
        hbm_bytes=total_hbm,
        collective_bytes=coll_total,
        collective_breakdown={**coll, "total": coll_total},
        multiplicity_ratio=(total_dot / raw_dot) if raw_dot else 1.0,
        n_whiles=n_whiles,
        trip_counts=sorted(set(trips), reverse=True)[:8],
    )
