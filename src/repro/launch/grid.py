"""Dry-run grid driver: one subprocess per cell (isolation + resumability).

Each cell runs `python -m repro.launch.dryrun --arch .. --shape .. --mesh ..`
in its own process so a compiler OOM/abort cannot take down the grid, and
XLA_FLAGS device-count forcing stays scoped to the dry-run entry point.
Existing result JSONs are skipped — rerun anytime to fill gaps.
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time

from repro.configs import SHAPES, list_archs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--archs", default=None, help="comma-separated subset")
    ap.add_argument("--shapes", default=None)
    ap.add_argument("--wbits", type=int, default=16)
    ap.add_argument("--timeout", type=int, default=3000)
    args = ap.parse_args(argv)

    archs = args.archs.split(",") if args.archs else list_archs()
    shapes = args.shapes.split(",") if args.shapes else list(SHAPES)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    cells = [(a, s, m) for a in archs for s in shapes for m in meshes]
    print(f"grid: {len(cells)} cells", flush=True)
    for i, (a, s, m) in enumerate(cells):
        tag = f"{a}__{s}__{m}" + (f"__w{args.wbits}" if args.wbits != 16
                                  else "")
        out_path = os.path.join(args.out, tag + ".json")
        if os.path.exists(out_path):
            print(f"[{i+1}/{len(cells)}] {tag}: cached", flush=True)
            continue
        t0 = time.perf_counter()   # monotonic: cell durations must not
        # absorb wall-clock jumps (NTP steps) mid-grid
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", a,
               "--shape", s, "--mesh", m, "--out", args.out,
               "--wbits", str(args.wbits)]
        try:
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout)
            tail = (r.stdout or r.stderr or "").strip().splitlines()
            msg = tail[-1] if tail else f"rc={r.returncode}"
        except subprocess.TimeoutExpired:
            msg = "TIMEOUT"
            import json
            with open(out_path, "w") as f:
                json.dump({"arch": a, "shape": s, "mesh": m,
                           "status": "timeout",
                           "timeout_s": args.timeout}, f)
        print(f"[{i+1}/{len(cells)}] {msg}  ({time.perf_counter()-t0:.0f}s)",
              flush=True)


if __name__ == "__main__":
    main()
