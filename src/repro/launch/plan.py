"""Planner driver: build → inspect → execute → serve (DESIGN.md §10).

    # build a waterfilled plan from calibration spectra
    PYTHONPATH=src python -m repro.launch.plan build --arch minicpm-2b \
        --reduced --target-bits 3 --out /tmp/plan.json --floor "*/attn/wo=4"

    # human-readable allocation + diff against another run
    PYTHONPATH=src python -m repro.launch.plan inspect --plan /tmp/plan.json

    # execute: parallel per-matrix quantization over host devices
    PYTHONPATH=src python -m repro.launch.plan execute --plan /tmp/plan.json \
        --workers 8 --compare-even

    # serve the mixed-rate model the plan implies
    PYTHONPATH=src python -m repro.launch.plan serve --plan /tmp/plan.json

The plan artifact carries its model provenance (arch/seed/calibration
shape), so `execute`/`serve` reconstruct the exact weights the plan was
built for — a plan is only valid against its own model.

Every subcommand takes ``--trace-out``/``--metrics-out``/``--events-out``
(DESIGN.md §11): `execute` exports per-task ``plan.task`` spans and the
``repro_plan_*`` counters/histograms, `serve` the full ``repro_serve_*``
engine instrumentation.  All reported durations are
``time.perf_counter()`` (monotonic), matching the engines' accounting.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.launch.serve import add_obs_flags, obs_export, obs_setup


def _parse_bound(items):
    out = {}
    for it in items or []:
        pat, _, val = it.rpartition("=")
        if not pat:
            raise SystemExit(f"--floor/--ceil wants PATTERN=BITS, got {it!r}")
        out[pat] = float(val)
    return out


def _build_model(prov):
    """Reconstruct (cfg, params, calib_batches) from plan provenance."""
    import jax

    from repro.configs import get_config
    from repro.data import DataConfig, global_batch_for_step
    from repro.models import init_params, split_tree
    cfg = get_config(prov["arch"])
    if prov.get("reduced"):
        cfg = cfg.reduced()
    params, _ = split_tree(init_params(cfg,
                                       jax.random.PRNGKey(prov["seed"])))
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=prov["seq_len"],
                      global_batch=prov["global_batch"])
    calib = [global_batch_for_step(dcfg, 10_000 + i)["tokens"]
             for i in range(prov["calib_batches"])]
    return cfg, params, calib


def _even_from(plan):
    """The even-spread RateBudget baseline, in plan form, over the SAME
    matrices (same names/weights) — the differential oracle.  Deliberately
    ignores per-matrix floors/ceilings: RateBudget spreads the budget
    uniformly, so this is the matched-budget comparison."""
    import dataclasses

    from repro.plan import QuantPlan
    from repro.plan.waterfill import payload_bits_for
    b = plan.budget_bits_per_param
    entries = [dataclasses.replace(
        e, target_bits=b, snapped_bits=b, payload_bits=payload_bits_for(b),
        achieved_bits=None, realized_distortion=None) for e in plan]
    return QuantPlan(budget_bits_per_param=b, weighting="even-spread",
                     entries=entries, provenance=dict(plan.provenance))


def _weighted_distortion(plan):
    vals = [(e.weight, e.n_params, e.realized_distortion) for e in plan]
    if any(v[2] is None for v in vals):
        return None
    return sum(w * n * d for w, n, d in vals)


def cmd_build(args):
    from repro.plan import build_plan, model_sensitivities
    prov = {"arch": args.arch, "reduced": bool(args.reduced),
            "seed": args.seed, "calib_batches": args.calib_batches,
            "seq_len": args.seq_len, "global_batch": args.global_batch}
    cfg, params, calib = _build_model(prov)
    t0 = time.perf_counter()
    sens = model_sensitivities(cfg, params, calib,
                               weighting=args.weighting, seed=args.seed,
                               floors=_parse_bound(args.floor),
                               ceils=_parse_bound(args.ceil))
    plan = build_plan(sens, args.target_bits, snap=not args.no_snap,
                      weighting=args.weighting, provenance=prov)
    plan.save(args.out)
    print(f"built plan for {len(sens)} matrices in {time.perf_counter()-t0:.1f}s "
          f"-> {args.out}")
    _print_summary(args.out)


def _print_summary(path):
    import json

    from repro.launch.summarize import plan_summary
    with open(path) as f:
        print(plan_summary(json.load(f)))


def cmd_inspect(args):
    from repro.plan import QuantPlan
    _print_summary(args.plan)
    if args.diff:
        delta = QuantPlan.load(args.plan).diff(QuantPlan.load(args.diff))
        print(f"\ndiff vs {args.diff}: "
              f"{'(allocations identical)' if not delta else ''}")
        for line in delta:
            print(f"  {line}")


def cmd_execute(args):
    from repro.plan import QuantPlan, quantize_model_with_plan
    plan = QuantPlan.load(args.plan)
    cfg, params, calib = _build_model(plan.provenance)
    t0 = time.perf_counter()
    _, _, plan, report = quantize_model_with_plan(
        cfg, params, calib, plan, n_workers=args.workers,
        devices="all" if args.pin_devices else None,
        compute_distortion=True)
    print(f"executed {len(plan.entries)} matrices on {args.workers} "
          f"worker(s) in {report.wall_s:.1f}s "
          f"(serial-equivalent {report.serial_s:.1f}s, "
          f"retries={report.retries}"
          + (f", stragglers={report.stragglers}" if report.stragglers
             else "") + ")")
    print(f"realized {plan.realized_bits_per_param:.3f} bits/param "
          f"(planned {plan.planned_bits_per_param:.3f})")
    out = args.out or args.plan.replace(".json", "") + ".executed.json"
    plan.save(out)
    reloaded = QuantPlan.load(out)
    assert reloaded == plan, "artifact round-trip mismatch"
    print(f"artifact round-trip OK -> {out}")
    if args.compare_even:
        even = _even_from(plan)
        _, _, even, _ = quantize_model_with_plan(
            cfg, params, calib, even, n_workers=args.workers,
            compute_distortion=True)
        d_wf, d_ev = _weighted_distortion(plan), _weighted_distortion(even)
        print(f"weighted output distortion: waterfilled {d_wf:.4e} vs "
              f"even-spread {d_ev:.4e} ({d_ev / max(d_wf, 1e-30):.2f}x)"
              f"  [realized {plan.realized_bits_per_param:.3f} vs "
              f"{even.realized_bits_per_param:.3f} bits/param]")
    print(f"wall {time.perf_counter()-t0:.1f}s")


def cmd_serve(args):
    import jax

    from repro.dist.sharding import use_mesh
    from repro.launch.mesh import make_host_mesh
    from repro.plan import QuantPlan
    from repro.quant import (leaf_format_histogram, quantize_params_tree,
                             qweight_bytes, serving_formats_from_plan)
    from repro.serve import ContinuousEngine, Request
    plan = QuantPlan.load(args.plan)
    cfg, params, _ = _build_model(plan.provenance)
    rng = np.random.default_rng(0)
    with use_mesh(make_host_mesh()):
        mixed = quantize_params_tree(
            params, nbits_by_path=serving_formats_from_plan(plan))
        qb, fb = qweight_bytes(mixed)
        print(f"mixed-rate serving formats: {leaf_format_histogram(mixed)}")
        print(f"  param bytes {qb/1e6:.2f} MB vs bf16 {fb/1e6:.2f} MB "
              f"({fb/max(qb,1):.2f}x HBM win)")
        eng = ContinuousEngine(cfg, mixed, n_slots=args.slots,
                               max_len=args.prompt_len + args.max_new + 2,
                               prefill_chunk=8)
        for i in range(args.requests):
            eng.submit(Request(
                rid=i, prompt=rng.integers(0, cfg.vocab, args.prompt_len)
                .astype(np.int32), max_new_tokens=args.max_new))
        t0 = time.perf_counter()
        done = eng.run_until_done()
        dt = time.perf_counter() - t0
        tok = sum(len(r.out_tokens) for r in done)
        print(f"served {len(done)} requests, {tok} tokens in {dt:.2f}s "
              f"({tok/dt:.1f} tok/s, continuous, mixed-rate)")
        return done


def main(argv=None):
    ap = argparse.ArgumentParser(prog="repro.launch.plan")
    sub = ap.add_subparsers(dest="cmd", required=True)

    b = sub.add_parser("build", help="waterfill a plan from calib spectra")
    b.add_argument("--arch", required=True)
    b.add_argument("--reduced", action="store_true")
    b.add_argument("--target-bits", type=float, default=3.0)
    b.add_argument("--weighting", default="output",
                   choices=["uniform", "output", "probe"])
    b.add_argument("--calib-batches", type=int, default=2)
    b.add_argument("--seq-len", type=int, default=32)
    b.add_argument("--global-batch", type=int, default=4)
    b.add_argument("--seed", type=int, default=0)
    b.add_argument("--floor", action="append", metavar="PATTERN=BITS",
                   help='per-matrix floor, e.g. "*/attn/wo=4" (repeatable)')
    b.add_argument("--ceil", action="append", metavar="PATTERN=BITS")
    b.add_argument("--no-snap", action="store_true",
                   help="keep the continuous allocation (no integer grid)")
    b.add_argument("--out", required=True)
    b.set_defaults(fn=cmd_build)

    i = sub.add_parser("inspect", help="summarize / diff a plan artifact")
    i.add_argument("--plan", required=True)
    i.add_argument("--diff", default=None)
    i.set_defaults(fn=cmd_inspect)

    e = sub.add_parser("execute", help="parallel plan execution")
    e.add_argument("--plan", required=True)
    e.add_argument("--workers", type=int, default=1)
    e.add_argument("--pin-devices", action="store_true",
                   help="round-robin tasks over all visible devices "
                        "(multi-device hosts; costs per-device compiles)")
    e.add_argument("--out", default=None)
    e.add_argument("--compare-even", action="store_true",
                   help="also execute the even-spread baseline and report "
                        "the weighted-distortion ratio")
    e.set_defaults(fn=cmd_execute)

    s = sub.add_parser("serve", help="serve the plan's mixed-rate formats")
    s.add_argument("--plan", required=True)
    s.add_argument("--requests", type=int, default=4)
    s.add_argument("--prompt-len", type=int, default=8)
    s.add_argument("--max-new", type=int, default=8)
    s.add_argument("--slots", type=int, default=4)
    s.set_defaults(fn=cmd_serve)

    for p in (b, i, e, s):
        add_obs_flags(p)

    args = ap.parse_args(argv)
    obs_setup(args)
    ret = args.fn(args)
    obs_export(args)
    return ret


if __name__ == "__main__":
    main()
