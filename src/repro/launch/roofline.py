"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds (TPU v5e constants):

    compute    = HLO_FLOPs_per_device / 197e12            (bf16 MXU peak)
    memory     = HLO_bytes_per_device / 819e9             (HBM bandwidth)
    collective = collective_bytes_per_device / (n_links × 50e9)

FLOPs/bytes come from ``compiled.cost_analysis()``.  Collective bytes are
NOT in cost_analysis: we parse the post-GSPMD HLO (``compiled.as_text()`` is
the per-partition module, so operand shapes are already per-device) and sum
operand sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops.

Also derives MODEL_FLOPS (6·N·D train, 2·N·D inference; N_active for MoE)
and the usefulness ratio MODEL_FLOPS / HLO_FLOPs (catches remat/dispatch
waste), and names the dominant term.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["HW", "collective_bytes_from_hlo", "roofline_terms",
           "model_flops", "RooflineReport"]


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12        # bf16 / chip (TPU v5e)
    hbm_bw: float = 819e9             # bytes/s per chip
    link_bw: float = 50e9             # bytes/s per ICI link
    n_links: int = 4                  # v5e: 4 ICI links per chip (2D torus)


DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 0.5, "u4": 0.5, "pred": 1,
}

_COLLECTIVE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> float:
    """Sum bytes over every tensor in an HLO result type string (handles
    tuples like (f32[8,128], u32[])."""
    total = 0.0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, float]:
    """Per-collective-kind byte totals (per device, post-partitioning).

    Uses the op *result* shape (for all-reduce = operand shape; for
    all-gather = gathered output, an upper bound on link bytes; for
    reduce-scatter = pre-reduce input... we use the result type consistently
    and report per-kind so the §Perf loop can reason about each).
    -start ops are counted once (-done carries the same tuple).
    """
    out: Dict[str, float] = {}
    seen_start = set()
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        line = m.group(0)
        if "-done(" in line:
            continue  # counted at -start
        b = _shape_bytes(shape_str)
        out[kind] = out.get(kind, 0.0) + b
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


def model_flops(n_params_active: int, tokens: int, kind: str) -> float:
    """6·N·D for training, 2·N·D for inference forward."""
    if kind == "train":
        return 6.0 * n_params_active * tokens
    return 2.0 * n_params_active * tokens


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops_per_device: float
    hlo_bytes_per_device: float
    collective_bytes_per_device: float
    collective_breakdown: Dict[str, float]
    model_flops_total: float
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    dominant: str = ""
    useful_ratio: float = 0.0
    bytes_per_device_peak: float = 0.0   # memory_analysis peak allocation

    def finalize(self, hw: HW = HW()) -> "RooflineReport":
        self.compute_s = self.hlo_flops_per_device / hw.peak_flops
        self.memory_s = self.hlo_bytes_per_device / hw.hbm_bw
        self.collective_s = self.collective_bytes_per_device / \
            (hw.n_links * hw.link_bw)
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.dominant = max(terms, key=terms.get)
        total_hlo_flops = self.hlo_flops_per_device * self.chips
        self.useful_ratio = (self.model_flops_total / total_hlo_flops
                             if total_hlo_flops else 0.0)
        return self

    @property
    def bound_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the chip's peak the dominant-term time implies for the
        useful model FLOPs: (MODEL_FLOPS/chips/peak) / bound_time."""
        if self.bound_time_s == 0:
            return 0.0
        ideal = self.model_flops_total / self.chips / HW().peak_flops
        return ideal / self.bound_time_s

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)


def report_from_artifacts(*, arch: str, shape: str, mesh: str, chips: int,
                          cost: Dict, hlo_text: str, model_flops_total: float,
                          mem_peak_bytes: float = 0.0) -> RooflineReport:
    """Build a report from compiled.cost_analysis() + HLO text.

    cost_analysis flops/bytes on a partitioned module are per-partition,
    but XLA counts while-loop bodies once — launch/hlo_cost.py re-derives
    dot FLOPs and collective bytes with trip-count weighting; raw
    cost_analysis bytes are scaled by the same loop-multiplicity ratio
    (documented approximation: loop bodies dominating flops dominate bytes).
    """
    from .hlo_cost import parse_hlo_costs
    hc = parse_hlo_costs(hlo_text)
    raw_flops = float(cost.get("flops", 0.0))
    raw_bytes = float(cost.get("bytes accessed", 0.0))
    flops = max(hc.dot_flops, raw_flops)
    # weighted per-op HBM accounting (hlo_cost); raw cost_analysis kept below
    bytes_scaled = hc.hbm_bytes if hc.hbm_bytes > 0 else raw_bytes
    rep = RooflineReport(
        arch=arch, shape=shape, mesh=mesh, chips=chips,
        hlo_flops_per_device=flops,
        hlo_bytes_per_device=bytes_scaled,
        collective_bytes_per_device=hc.collective_bytes,
        collective_breakdown=hc.collective_breakdown,
        model_flops_total=model_flops_total,
        bytes_per_device_peak=mem_peak_bytes,
    )
    rep = rep.finalize()
    rep.collective_breakdown["raw_cost_flops"] = raw_flops
    rep.collective_breakdown["raw_cost_bytes"] = raw_bytes
    rep.collective_breakdown["loop_multiplicity"] = hc.multiplicity_ratio
    return rep
