"""Quickstart: quantize one linear layer with WaterSIC and compare to GPTQ.

Shows the core rate-distortion claim of the paper on a single (a×n) weight
matrix with an ill-conditioned activation covariance: at matched rate,
WaterSIC's distortion beats Huffman-GPTQ's, and its measured gap to the
waterfilling bound is ≈ 0.255 bits (Theorem 3.3).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (CalibStats, GAP_CUBE_BITS, chol_lower,
                        column_entropies, gptq_gap_bits, gptq_via_zsic,
                        high_rate_bound, layer_distortion, plain_watersic,
                        quantize_at_rate, random_covariance)


def main():
    rng = np.random.default_rng(0)
    a, n = 4096, 64
    sigma, _ = random_covariance(n, condition=300.0, seed=1)
    w = rng.standard_normal((a, n))

    print("== PlainWaterSIC vs GPTQ (matched lattice density) ==")
    ws = plain_watersic(w, sigma, alpha=0.05)
    gq = gptq_via_zsic(w, sigma, alpha=0.05)
    for name, out in (("WaterSIC", ws), ("Huffman-GPTQ", gq)):
        rate = column_entropies(out["codes"]).mean()
        gap = rate - high_rate_bound(out["distortion"], 1.0, sigma)
        print(f"  {name:13s} rate={rate:.3f} b/w  D={out['distortion']:.3e}"
              f"  gap-to-IT={gap:+.3f} bits")
    print(f"  theory: WaterSIC gap={GAP_CUBE_BITS:.3f}, "
          f"GPTQ gap={gptq_gap_bits(np.diag(chol_lower(sigma))):.3f}")

    print("\n== Full WaterSIC (Alg. 3) at a target rate ==")
    stats = CalibStats(sigma_x=jnp.asarray(sigma, jnp.float32))
    for bits in (2.0, 3.0, 4.0):
        q = quantize_at_rate(jnp.asarray(w, jnp.float32), stats, bits)
        d = layer_distortion(w.astype(np.float32), q, sigma)
        print(f"  target={bits:.1f}  entropy={q.entropy_bits:.3f}  "
              f"rate_eff={q.rate_eff:.3f}  D={d:.3e}  "
          f"dead={int(q.dead_mask.sum())}")


if __name__ == "__main__":
    main()
