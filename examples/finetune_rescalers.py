"""WaterSIC-FT example: quantize at a low rate, then recover quality by
finetuning only the rescaler vectors (t, γ) under KL distillation —
the paper's Table 1 "WaterSIC-FT" rows.

    PYTHONPATH=src python examples/finetune_rescalers.py
"""
import numpy as np

from repro.data import global_batch_for_step
from repro.quant.pipeline import PTQConfig, model_ppl, quantize_model
from repro.train.distill import finetune_rescalers

from quantize_model import build_and_train


def main():
    print("== training base model ==")
    cfg, params, dcfg = build_and_train(steps=300)
    calib = [global_batch_for_step(dcfg, 10_000 + i)["tokens"]
             for i in range(2)]
    evalb = [np.concatenate(
        [global_batch_for_step(dcfg, 20_000 + i)["tokens"],
         global_batch_for_step(dcfg, 20_000 + i)["targets"][:, -1:]], axis=1)
        for i in range(2)]
    print(f"fp PPL: {model_ppl(cfg, params, evalb):.3f}")

    bits = 1.5
    qp, qlin, budget, _ = quantize_model(
        cfg, params, calib, PTQConfig(target_bits=bits, method="watersic"))
    ppl_q = model_ppl(cfg, qp, evalb)
    print(f"WaterSIC @{bits}b  PPL: {ppl_q:.3f} "
          f"(rate {budget.realized_rate:.3f})")

    print("== finetuning rescalers (KL distillation) ==")
    ft_batches = [global_batch_for_step(dcfg, 30_000 + i)["tokens"]
                  for i in range(4)]
    qp_ft, _, losses = finetune_rescalers(cfg, params, qp, qlin, ft_batches,
                                          steps=60)
    ppl_ft = model_ppl(cfg, qp_ft, evalb)
    print(f"WaterSIC-FT @{bits}b PPL: {ppl_ft:.3f} "
          f"(KL {losses[0]:.4f} → {losses[-1]:.4f})")
    assert ppl_ft <= ppl_q * 1.02, "FT should not hurt"


if __name__ == "__main__":
    main()
