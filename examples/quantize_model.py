"""End-to-end driver: train a small LM for a few hundred steps, then PTQ it
with WaterSIC / Huffman-GPTQ / RTN across rates and evaluate perplexity —
the in-repo analogue of the paper's Tables 1/2.

    PYTHONPATH=src python examples/quantize_model.py [--steps 300]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.data import DataConfig, global_batch_for_step
from repro.models import init_params, split_tree
from repro.quant.pipeline import PTQConfig, model_ppl, quantize_model
from repro.train import AdamWConfig, TrainState, adamw_init, make_train_step


def build_and_train(steps=300, seed=0):
    cfg = ArchConfig(name="lm-20m", family="dense", n_layers=4, d_model=128,
                     n_heads=8, n_kv=4, d_ff=384, vocab=512, head_dim=16)
    params, _ = split_tree(init_params(cfg, jax.random.PRNGKey(seed)))
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=16)
    opt = AdamWConfig(lr=2e-3, total_steps=steps, warmup_steps=steps // 20)
    state = TrainState(params=params, opt=adamw_init(params), err=None)
    step = jax.jit(make_train_step(cfg, opt))
    t0 = time.time()
    for s in range(steps):
        batch = jax.tree.map(jnp.asarray, global_batch_for_step(dcfg, s))
        state, m = step(state, batch)
        if s % 50 == 0:
            print(f"  train step {s:4d} loss {float(m['loss']):.4f}")
    print(f"  trained {steps} steps in {time.time()-t0:.0f}s "
          f"(final loss {float(m['loss']):.4f})")
    return cfg, state.params, dcfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--rates", default="1.5,2.0,3.0")
    ap.add_argument("--calib-batches", type=int, default=2)
    args = ap.parse_args()

    print("== training the base model ==")
    cfg, params, dcfg = build_and_train(args.steps)
    calib = [global_batch_for_step(dcfg, 10_000 + i)["tokens"]
             for i in range(args.calib_batches)]
    evalb = [np.concatenate(
        [global_batch_for_step(dcfg, 20_000 + i)["tokens"],
         global_batch_for_step(dcfg, 20_000 + i)["targets"][:, -1:]], axis=1)
        for i in range(2)]
    ppl_fp = model_ppl(cfg, params, evalb)
    print(f"\nunquantized PPL: {ppl_fp:.3f}\n")
    print(f"{'rate':>5s} {'method':>15s} {'realized':>9s} {'PPL':>9s}")
    for bits in [float(r) for r in args.rates.split(",")]:
        for method in ("watersic", "hptq", "rtn"):
            qp, qlin, budget, _ = quantize_model(
                cfg, params, calib, PTQConfig(target_bits=bits,
                                              method=method))
            ppl = model_ppl(cfg, qp, evalb)
            print(f"{bits:5.2f} {method:>15s} {budget.realized_rate:9.3f} "
                  f"{ppl:9.3f}", flush=True)


if __name__ == "__main__":
    main()
