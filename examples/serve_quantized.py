"""Serving example: batched requests against a WaterSIC-quantized model.

Quantizes a small trained LM with real WaterSIC codes (from the PTQ
pipeline), installs them as int8 serving weights (quant.from_watersic: the
weights the engine reads are int8 codes + fused scales, as on TPU), serves
batched greedy generations, and cross-checks the first logits against the
dequantized float path.

    PYTHONPATH=src python examples/serve_quantized.py
"""
from collections import defaultdict

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import global_batch_for_step
from repro.models import decode_step, init_cache
from repro.quant import from_watersic
from repro.quant.pipeline import PTQConfig, quantize_model
from repro.serve import Request, ServeEngine

from quantize_model import build_and_train


def install_codes(qparams, qlinears, n_layers):
    """Swap dequantized float weights for stacked int8 code dicts."""
    groups = defaultdict(dict)
    for name, q in qlinears.items():
        l = int(name.split("/")[0][1:])
        groups[tuple(name.split("/")[1:])][l] = from_watersic(q)
    p = jax.tree.map(lambda x: x, qparams)
    for path, per_layer in groups.items():
        assert len(per_layer) == n_layers, (path, sorted(per_layer))
        stacked = {k: jnp.stack([per_layer[l][k] for l in range(n_layers)])
                   for k in ("codes", "s", "t")}
        node = p["layers"]
        for k in path[:-1]:
            node = node[k]
        node[path[-1]] = {**node[path[-1]], "w": stacked}
    return p


def main():
    cfg, params, dcfg = build_and_train(steps=200)
    calib = [global_batch_for_step(dcfg, 10_000)["tokens"]]
    qp, qlin, budget, _ = quantize_model(
        cfg, params, calib, PTQConfig(target_bits=3.0, method="watersic"))
    print(f"quantized at realized rate {budget.realized_rate:.3f} b/w")

    qp_int8 = install_codes(qp, qlin, cfg.n_layers)

    # cross-check: int8 serving path ≈ dequantized float path
    tok = jnp.zeros((2, 1), jnp.int32)
    lg_f, _ = decode_step(cfg, qp, init_cache(cfg, 2, 16, jnp.float32), tok)
    lg_q, _ = decode_step(cfg, qp_int8,
                          init_cache(cfg, 2, 16, jnp.float32), tok)
    err = float(jnp.abs(lg_f - lg_q).max())
    print(f"int8-path vs float-path max logit err: {err:.2e}")

    rng = np.random.default_rng(0)
    eng = ServeEngine(cfg, qp_int8, n_slots=4, max_len=48)
    for i in range(6):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab, 8,
                                               dtype=np.int32),
                           max_new_tokens=8))
    done = eng.run_until_done()
    for r in done:
        print(f"  rid={r.rid} -> {r.out_tokens}")
    print(f"served {len(done)} requests from int8 WaterSIC codes")


if __name__ == "__main__":
    main()
